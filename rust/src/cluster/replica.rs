//! A serving replica: one GPU's memory hierarchy plus a step-granular
//! decode loop.
//!
//! Each [`Replica`] owns the full single-GPU simulation stack — per-layer
//! [`ExpertCache`]s, a [`TransferEngine`] for PCIe accounting, a VRAM
//! budget-derived capacity, and its own [`SimClock`] — and serves its
//! queue the way the engine's `DecodeSession` does: sequences occupy
//! decode slots, every [`Replica::run_one_step`] advances the whole live
//! batch one step — decodes by one token, prompts still in prefill by up
//! to [`Replica::with_prefill_chunk`] prompt tokens piggybacked on the
//! same step (Sarathi-style chunked prefill) — and a sequence retires
//! the moment its trace ends, so its slot re-admits from the queue
//! *mid-flight* (continuous batching).  [`SchedulerMode::Static`] gates
//! admission on an empty slot set, recovering the legacy
//! run-to-completion batch for comparison.
//!
//! Costing follows the engine's Eq. 3 decomposition at step granularity:
//! each step charges attention/head amortized over *every token the step
//! consumes* plus grouped expert execution over the step's *actual*
//! distinct-expert working set (a prefill chunk's union streams once),
//! and replays the batch's pre-drawn routing traces against the
//! *persistent* caches to add the `N_miss · Time_transfer` term.
//! Persistence across requests is the point: a replica that keeps
//! serving the same task's traffic stays hit-bound, which is what
//! affinity routing exploits — and what makes mid-flight admission of
//! same-task requests cheap.

use std::collections::VecDeque;

use crate::cache::{EvictionKind, ExpertCache};
use crate::clock::{CostModel, GpuSpec, PaperDims, SimClock};
use crate::coordinator::{Outcome, PreemptPolicy, Priority, SchedulerMode};
use crate::fault::Health;
use crate::pcie::TransferEngine;
use crate::predictor::PrefetchPlan;
use crate::quant::QuantMode;
use crate::trace::{PcieSnap, Recorder, Trace, TraceEvent};
use crate::vram::VramBudget;

use super::balancer::ReplicaView;
use super::workload::ClusterRequest;

/// Static description of one replica's model + memory configuration.
#[derive(Debug, Clone)]
pub struct ReplicaSpec {
    pub n_layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    /// GPU-resident experts per layer (derived from the VRAM ledger).
    pub capacity: usize,
    pub eviction: EvictionKind,
    pub quant: QuantMode,
    /// Keep low-bit little copies of the hottest experts resident (the
    /// big-little fallback; `None` disables).  The little store carves
    /// its bytes out of the same VRAM budget — see `cache`.
    pub little_tier: Option<QuantMode>,
    /// Execute a missed expert's little copy degraded, at zero stall,
    /// when the expected wait on the full-tier transfer exceeds this
    /// many simulated seconds (`--fallback-threshold`).
    pub fallback_threshold: f64,
    /// Refresh the union prefetch plan of the in-flight set on admission.
    pub prefetch: bool,
    /// Layer-ahead transfer pipeline depth (`--lookahead`): during layer
    /// ℓ's compute, prefetch the next `lookahead` layers' upcoming
    /// expert sets non-blocking; 0 disables (admit-time prefetch only).
    pub lookahead: usize,
    pub gpu: GpuSpec,
    pub dims: PaperDims,
}

impl ReplicaSpec {
    /// OLMoE at paper scale under the paper's 3 GB VRAM budget (§4.1);
    /// per-layer capacity comes from the [`VramBudget`] ledger.
    pub fn olmoe(gpu: GpuSpec) -> ReplicaSpec {
        let dims = PaperDims {
            n_layers: 16,
            n_experts: 64,
            top_k: 8,
            d_model: 2048,
            d_ff: 1024,
            vocab: 50304,
        };
        ReplicaSpec::from_vram_gb(gpu, dims, 3.0)
    }

    /// Derive per-layer expert capacity from a VRAM budget in GB.
    pub fn from_vram_gb(gpu: GpuSpec, dims: PaperDims, vram_gb: f64) -> ReplicaSpec {
        let quant = QuantMode::Int4;
        let capacity = VramBudget::gb(vram_gb, dims).capacity_per_layer(quant).max(1);
        ReplicaSpec {
            n_layers: dims.n_layers,
            n_experts: dims.n_experts,
            top_k: dims.top_k,
            capacity,
            eviction: EvictionKind::Lfu,
            quant,
            little_tier: None,
            fallback_threshold: 0.0,
            prefetch: true,
            lookahead: 0,
            gpu,
            dims,
        }
    }

    /// Serving-tier override (spec-level; `ClusterConfig::with_quant`
    /// additionally rescales capacity to preserve the VRAM byte budget).
    pub fn with_quant(mut self, quant: QuantMode) -> ReplicaSpec {
        self.quant = quant;
        self
    }

    /// Layer-ahead transfer pipeline depth (0 = admit-time prefetch only).
    pub fn with_lookahead(mut self, depth: usize) -> ReplicaSpec {
        self.lookahead = depth;
        self
    }

    /// Big-little fallback: little-tier copies of the hottest experts,
    /// executed degraded when the expected transfer wait exceeds
    /// `threshold` simulated seconds (`None` disables).
    pub fn with_fallback(mut self, little: Option<QuantMode>, threshold: f64) -> ReplicaSpec {
        self.little_tier = little;
        self.fallback_threshold = threshold.max(0.0);
        self
    }

    pub fn cost_model(&self) -> CostModel {
        CostModel::new(self.gpu.clone(), self.dims)
    }

    /// Analytic compute-only service time of one request decoded alone
    /// (no transfer stalls) — used to auto-scale offered load.
    pub fn est_service_seconds(&self, prompt_tokens: usize, max_output: usize) -> f64 {
        let cost = self.cost_model();
        let steps = (prompt_tokens + max_output) as f64;
        let per_step = self.n_layers as f64
            * (cost.attn_time(1) + cost.expert_exec_time(self.top_k, self.top_k, self.quant))
            + cost.head_time(1);
        steps * per_step
    }
}

/// One finished request, in the replica's simulated timeline.
#[derive(Debug, Clone)]
pub struct Completion {
    pub request_id: u64,
    pub task: usize,
    pub priority: Priority,
    pub arrival: f64,
    /// Admitted into a decode slot.
    pub started: f64,
    /// First output token landed.
    pub first_token: f64,
    pub finished: f64,
    pub output_tokens: usize,
    /// Simulated seconds spent suspended after preemptions (0.0 when the
    /// request was never preempted) — reported separately from queueing
    /// so preemption cost stays visible.
    pub preempted_wait: f64,
    /// How the request ended: `Completed` (full output), `Cancelled`
    /// (client hang-up — partial output), or `Rejected` (admission turned
    /// it away; no output).  Latency percentiles sample `Completed` only.
    pub outcome: Outcome,
    /// The request's absolute TTFT deadline, carried through so the
    /// report can score goodput (deadline-free completions always attain).
    pub deadline: Option<f64>,
}

impl Completion {
    pub fn queue_wait(&self) -> f64 {
        (self.started - self.arrival).max(0.0)
    }

    /// Time-to-first-token from arrival.
    pub fn ttft(&self) -> f64 {
        (self.first_token - self.arrival).max(0.0)
    }

    /// Time per output token after the first.
    pub fn tpot(&self) -> f64 {
        if self.output_tokens <= 1 {
            return 0.0;
        }
        (self.finished - self.first_token).max(0.0) / (self.output_tokens - 1) as f64
    }

    pub fn latency(&self) -> f64 {
        (self.finished - self.arrival).max(0.0)
    }

    /// `true` when this completion's tokens count toward goodput: the
    /// request completed and its first token landed within its deadline
    /// (deadline-free completions always attain).
    pub fn attained(&self) -> bool {
        self.outcome == Outcome::Completed
            && self.deadline.map_or(true, |d| self.first_token <= d)
    }
}

/// One in-flight sequence: its pre-drawn request plus a step cursor into
/// the routing trace.
struct ActiveSeq {
    req: ClusterRequest,
    step: usize,
    started: f64,
    first_token: f64,
    /// Simulated seconds this sequence has spent suspended so far.
    preempted_wait: f64,
}

/// A live sequence detached from one replica for adoption by another
/// (brownout migration — see `fault` and the cluster loop).  `ActiveSeq`
/// is private; this is the portable wrapper: the step cursor and timing
/// carry over verbatim, so the adopted sequence resumes its pre-drawn
/// routing exactly where it stopped and its tokens stay bit-identical.
#[derive(Debug, Clone)]
pub struct MigratedSeq {
    pub req: ClusterRequest,
    pub step: usize,
    pub started: f64,
    pub first_token: f64,
    pub preempted_wait: f64,
    /// Sim time the sequence was detached (suspension-wait accounting).
    pub since: f64,
}

/// One serving replica (see module docs).
pub struct Replica {
    pub id: usize,
    pub spec: ReplicaSpec,
    cost: CostModel,
    pub cache: ExpertCache,
    pub pcie: TransferEngine,
    pub clock: SimClock,
    scheduler: SchedulerMode,
    /// Prompt tokens a prefilling sequence consumes per step (≥ 1).
    prefill_chunk: usize,
    /// When a waiting higher-priority request may preempt an in-flight
    /// sequence (mirrors the coordinator's `--preempt` policy).
    preempt: PreemptPolicy,
    /// SLO-aware admission control (mirrors the coordinator's
    /// `--admission`): a deadline-tagged request whose compute-optimistic
    /// TTFT estimate cannot meet its deadline is rejected at admission
    /// instead of occupying a slot only to miss at p99.
    admission: bool,
    /// Promote a queued or suspended request one priority class after it
    /// has waited this long, two classes after twice as long; `None`
    /// disables aging (`--age-promote`).
    age_promote: Option<f64>,
    /// Pending arrivals, one FIFO queue per [`Priority`] class.
    queues: [VecDeque<ClusterRequest>; 3],
    in_flight: Vec<ActiveSeq>,
    /// Preempted sequences waiting to reattach: (sequence, suspended-at).
    suspended: Vec<(ActiveSeq, f64)>,
    /// Sequences suspended out of their slot by a higher-priority waiter.
    pub preemptions: u64,
    /// Queued or suspended requests aged up a priority class on this
    /// replica (`--age-promote`).
    pub promotions: u64,
    /// (token, expert) assignments served degraded from a little-tier
    /// copy (big-little fallback).
    pub degraded_execs: u64,
    /// All routed (token, expert) assignments replayed so far — the
    /// denominator of [`Replica::degraded_token_frac`].
    pub total_assignments: u64,
    /// Per-layer routed-assignment counts accumulated from the replayed
    /// traces: the signal the little store's hottest-set refresh ranks by
    /// (the replica-side analogue of the engine's `ActivationTrace`).
    route_counts: Vec<Vec<u64>>,
    /// Prefetch plan of the most recently enqueued request: the replica's
    /// *planned* residency, which the affinity scorer may consult before
    /// the caches have warmed (burst arrivals dispatch ahead of decode).
    last_plan: Option<PrefetchPlan>,
    /// Fault-injection state (see `fault`): health, the sim time a
    /// crashed replica comes back up, and the active degradation
    /// windows.  Inert at the defaults — `slow_factor` 1.0 multiplies
    /// compute bit-exactly and `Healthy` contributes zero balancer
    /// bias — so fault-free runs stay byte-identical.
    health: Health,
    recover_at: f64,
    slow_factor: f64,
    brownout_until: f64,
    flap_until: f64,
    escalated: bool,
    /// Structured event recorder on this replica's lane (see `trace`);
    /// off by default — a disabled recorder adds no allocation to the
    /// step path.
    rec: Recorder,
    pub completions: Vec<Completion>,
    pub busy_seconds: f64,
    pub peak_queue_depth: usize,
}

impl Replica {
    pub fn new(id: usize, spec: ReplicaSpec, scheduler: SchedulerMode) -> Replica {
        let mut cache =
            ExpertCache::new(spec.n_layers, spec.n_experts, spec.capacity, spec.eviction);
        cache.set_tiers(spec.quant, spec.little_tier);
        let cost = spec.cost_model();
        let route_counts = vec![vec![0; spec.n_experts]; spec.n_layers];
        Replica {
            id,
            spec,
            cost,
            cache,
            pcie: TransferEngine::new(),
            clock: SimClock::new(),
            scheduler,
            prefill_chunk: 1,
            preempt: PreemptPolicy::Off,
            admission: false,
            age_promote: None,
            queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            in_flight: Vec::new(),
            suspended: Vec::new(),
            preemptions: 0,
            promotions: 0,
            degraded_execs: 0,
            total_assignments: 0,
            route_counts,
            last_plan: None,
            health: Health::Healthy,
            recover_at: 0.0,
            slow_factor: 1.0,
            brownout_until: 0.0,
            flap_until: 0.0,
            escalated: false,
            rec: Recorder::off(),
            completions: Vec::new(),
            busy_seconds: 0.0,
            peak_queue_depth: 0,
        }
    }

    /// Set the per-step prompt-token budget (chunked prefill; clamped to
    /// ≥ 1, where 1 is token-at-a-time prefill).
    pub fn with_prefill_chunk(mut self, chunk: usize) -> Replica {
        self.prefill_chunk = chunk.max(1);
        self
    }

    /// Set the preemption policy (see [`PreemptPolicy`]).
    pub fn with_preempt(mut self, preempt: PreemptPolicy) -> Replica {
        self.preempt = preempt;
        self
    }

    /// Enable (or disable) SLO-aware admission control.
    pub fn with_admission(mut self, on: bool) -> Replica {
        self.admission = on;
        self
    }

    /// Arm age-based priority promotion: a queued or suspended request
    /// that has waited `tau` sim seconds is promoted one class, two
    /// classes after `2·tau`.  Non-positive or non-finite `tau` disables
    /// aging, same as `None`.
    pub fn with_age_promote(mut self, tau: Option<f64>) -> Replica {
        self.age_promote = tau.filter(|t| t.is_finite() && *t > 0.0);
        self
    }

    /// Enable (or disable) sim-time structured tracing: the replica's
    /// lane in the merged fleet timeline is its id.
    pub fn with_trace(mut self, on: bool) -> Replica {
        self.rec = if on {
            Recorder::on(self.id as u32, &format!("replica {}", self.id))
        } else {
            Recorder::off()
        };
        self
    }

    /// Drain the recorded event stream (`None` when tracing was off).
    pub fn take_trace(&mut self) -> Option<Trace> {
        self.rec.take()
    }

    /// Fraction of routed assignments served degraded by the big-little
    /// fallback (0.0 when the fallback is off; always in [0, 1]).
    pub fn degraded_token_frac(&self) -> f64 {
        crate::metrics::degraded_frac(self.degraded_execs, self.total_assignments)
    }

    /// Current health (see [`Health`]); drives the balancer's
    /// dispatchability filter and de-weighting.
    pub fn health(&self) -> Health {
        self.health
    }

    /// Sim time a crashed replica comes back up (0.0 if never crashed).
    pub fn recover_at(&self) -> f64 {
        self.recover_at
    }

    /// Advance the health state machine to `now`: expired degradation
    /// windows reset their multipliers (`Degraded` turns `Healthy` once
    /// both compute and link are nominal), and a `Down` replica whose
    /// outage has elapsed turns `Recovering` — dispatchable again, and
    /// promoted back to `Healthy` by its first served step.  Inert when
    /// no fault state is set.
    pub fn refresh_health(&mut self, now: f64) {
        if self.slow_factor != 1.0 && now >= self.brownout_until {
            self.slow_factor = 1.0;
        }
        if self.pcie.slowdown() != 1.0 && now >= self.flap_until {
            self.pcie.set_slowdown(1.0);
        }
        match self.health {
            Health::Down if now >= self.recover_at => self.health = Health::Recovering,
            Health::Degraded if self.slow_factor == 1.0 && self.pcie.slowdown() == 1.0 => {
                self.health = Health::Healthy;
            }
            _ => {}
        }
    }

    /// Brownout: compute runs `factor`× slower until sim time `until`
    /// and the replica reads `Degraded` to the balancer.
    pub fn set_brownout(&mut self, factor: f64, until: f64) {
        self.slow_factor = factor.max(1.0);
        self.brownout_until = until;
        if self.health != Health::Down {
            self.health = Health::Degraded;
        }
    }

    /// PCIe link flap: the link runs `factor`× slower until sim time
    /// `until`, and every transfer in flight at the flap is lost — its
    /// reservation releases and the consumer re-fetches via the normal
    /// demand path (issue-side byte accounting stays; the trace's
    /// prefetch audit counts the loss).
    pub fn apply_link_flap(&mut self, factor: f64, until: f64) {
        self.pcie.set_slowdown(factor);
        self.flap_until = until;
        if self.health != Health::Down {
            self.health = Health::Degraded;
        }
        let now = self.clock.now();
        for (l, e) in self.pcie.drop_in_flight() {
            self.rec.emit(now, TraceEvent::TransferLost { layer: l as u32, expert: e as u32 });
            self.cache.layer(l).unreserve(e);
        }
    }

    /// Corrupt the oldest clean in-flight transfer (a checksum failure,
    /// observable only at arrival — see `pcie`).  Returns whether a
    /// transfer was there to corrupt.
    pub fn corrupt_transfer(&mut self) -> bool {
        self.pcie.corrupt_oldest_in_flight().is_some()
    }

    /// Escalate (or reset) the big-little fallback threshold to zero:
    /// while part of the fleet is down, every miss backed by a little
    /// copy serves degraded instead of stalling — graceful degradation
    /// before load shedding.  No-op without a little tier.
    pub fn set_fallback_escalation(&mut self, on: bool) {
        self.escalated = on;
    }

    fn fallback_threshold(&self) -> f64 {
        if self.escalated {
            0.0
        } else {
            self.spec.fallback_threshold
        }
    }

    /// Crash this replica: every live, suspended, and queued request is
    /// reclaimed (returned for the coordinator to retry elsewhere), GPU
    /// state — both cache tiers, reservations, in-flight transfers — is
    /// lost, and the replica is `Down` until `recover_at`.  Pin-ledger
    /// entries of in-flight sequences release exactly once here
    /// (suspended sequences already released at suspension), so the
    /// trace's pin conservation audit balances across the crash.
    pub fn crash(&mut self, recover_at: f64) -> Vec<ClusterRequest> {
        let now = self.clock.now();
        let reclaimed = self.in_flight.len() + self.suspended.len() + self.queue_depth();
        self.rec.emit(
            now,
            TraceEvent::Crash { replica: self.id as u32, reclaimed: reclaimed as u32 },
        );
        let mut reqs = Vec::with_capacity(reclaimed);
        for seq in self.in_flight.drain(..) {
            self.cache.release(seq.req.id);
            self.rec.emit(now, TraceEvent::PinRelease { owner: seq.req.id });
            reqs.push(seq.req);
        }
        for (seq, _) in self.suspended.drain(..) {
            reqs.push(seq.req);
        }
        for q in &mut self.queues {
            reqs.extend(q.drain(..));
        }
        for (l, e) in self.pcie.drop_in_flight() {
            self.rec.emit(now, TraceEvent::TransferLost { layer: l as u32, expert: e as u32 });
        }
        for l in 0..self.spec.n_layers {
            let (big, little) = self.cache.layer(l).crash_clear();
            for e in big {
                self.rec.emit(now, TraceEvent::CacheEvict { layer: l as u32, expert: e as u32 });
            }
            for e in little {
                self.rec.emit(now, TraceEvent::LittleEvict { layer: l as u32, expert: e as u32 });
            }
        }
        self.last_plan = None;
        self.health = Health::Down;
        self.recover_at = recover_at;
        self.slow_factor = 1.0;
        self.pcie.set_slowdown(1.0);
        if recover_at > now {
            self.clock.advance(recover_at - now);
        }
        reqs
    }

    /// Detach every live and suspended sequence for adoption by a healthy
    /// replica (brownout migration).  In-flight sequences release their
    /// pin-ledger entries here — the adopter re-pins at reattachment —
    /// so each lane's pin conservation stays balanced.
    pub fn extract_live(&mut self) -> Vec<MigratedSeq> {
        let now = self.clock.now();
        let mut out = Vec::with_capacity(self.in_flight.len() + self.suspended.len());
        for seq in self.in_flight.drain(..) {
            self.cache.release(seq.req.id);
            self.rec.emit(now, TraceEvent::Suspend { seq: seq.req.id });
            self.rec.emit(now, TraceEvent::PinRelease { owner: seq.req.id });
            out.push(MigratedSeq {
                req: seq.req,
                step: seq.step,
                started: seq.started,
                first_token: seq.first_token,
                preempted_wait: seq.preempted_wait,
                since: now,
            });
        }
        for (seq, since) in self.suspended.drain(..) {
            out.push(MigratedSeq {
                req: seq.req,
                step: seq.step,
                started: seq.started,
                first_token: seq.first_token,
                preempted_wait: seq.preempted_wait,
                since,
            });
        }
        out
    }

    /// Adopt a migrated sequence: it lands suspended (reattachment
    /// re-runs the plan refresh and re-pins) and the clock fast-forwards
    /// to the migration time so the adopter cannot serve it in its own
    /// past.
    pub fn adopt(&mut self, m: MigratedSeq, now: f64) {
        if now > self.clock.now() {
            self.clock.advance(now - self.clock.now());
        }
        self.last_plan = Some(m.req.plan.clone());
        self.suspended.push((
            ActiveSeq {
                req: m.req,
                step: m.step,
                started: m.started,
                first_token: m.first_token,
                preempted_wait: m.preempted_wait,
            },
            m.since,
        ));
    }

    pub fn enqueue(&mut self, req: ClusterRequest) {
        self.last_plan = Some(req.plan.clone());
        self.queues[req.priority.idx()].push_back(req);
        self.peak_queue_depth = self.peak_queue_depth.max(self.queue_depth());
    }

    pub fn queue_depth(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Live decode-slot occupancy (the in-flight sequence count).
    pub fn slots_in_use(&self) -> usize {
        self.in_flight.len()
    }

    /// Preempted sequences waiting to reattach.
    pub fn suspended_len(&self) -> usize {
        self.suspended.len()
    }

    pub fn has_work(&self) -> bool {
        !self.in_flight.is_empty()
            || !self.suspended.is_empty()
            || self.queues.iter().any(|q| !q.is_empty())
    }

    pub fn busy_until(&self) -> f64 {
        self.clock.now()
    }

    /// Queued plus in-flight Low-class requests — the preemption-headroom
    /// signal the priority-aware balancer prices at dispatch.
    pub fn low_load(&self) -> usize {
        self.queues[Priority::Low.idx()].len()
            + self.in_flight.iter().filter(|s| s.req.priority == Priority::Low).count()
    }

    /// This replica's dispatch-facing state — the single source of truth
    /// behind balancer views and the steal scan.  Every field reads an
    /// O(1) counter or an O(slots) scan; `overlap` is left 0.0, the one
    /// O(plan) field, for the caller to fill only when its balancer
    /// actually prices affinity.
    pub fn view(&self) -> ReplicaView {
        ReplicaView {
            id: self.id,
            queue_depth: self.queue_depth(),
            slots_in_use: self.slots_in_use(),
            busy_until: self.busy_until(),
            overlap: 0.0,
            low_load: self.low_load(),
            health: self.health(),
        }
    }

    /// The queued request a thief would take: the back of the
    /// lowest-priority nonempty queue.  Tail steals never reorder a
    /// class's FIFO, and the lowest class loses work first.
    pub fn steal_candidate_queued(&self) -> Option<&ClusterRequest> {
        Priority::ALL.iter().find_map(|p| self.queues[p.idx()].back())
    }

    /// Remove and return the queued steal candidate
    /// ([`Replica::steal_candidate_queued`]).
    pub fn take_steal_queued(&mut self) -> Option<ClusterRequest> {
        self.queues.iter_mut().find(|q| !q.is_empty()).and_then(|q| q.pop_back())
    }

    /// The suspended sequence a thief would live-steal — lowest priority
    /// class, then least sunk suspension wait (latest `since`): the one
    /// the local scheduler wants back last.  Returns its request and
    /// decode step (the KV-transfer size drivers).
    pub fn steal_candidate_live(&self) -> Option<(&ClusterRequest, usize)> {
        self.suspended
            .iter()
            .min_by(|a, b| a.0.req.priority.cmp(&b.0.req.priority).then(b.1.total_cmp(&a.1)))
            .map(|(s, _)| (&s.req, s.step))
    }

    /// Remove and return the live steal candidate
    /// ([`Replica::steal_candidate_live`]) as a portable suspended
    /// sequence, keeping its original suspension instant — its pins were
    /// already released at preemption, so nothing unwinds here.
    pub fn take_steal_suspended(&mut self) -> Option<MigratedSeq> {
        let i = self
            .suspended
            .iter()
            .enumerate()
            .min_by(|a, b| {
                a.1 .0.req.priority.cmp(&b.1 .0.req.priority).then(b.1 .1.total_cmp(&a.1 .1))
            })
            .map(|(i, _)| i)?;
        let (seq, since) = self.suspended.remove(i);
        Some(MigratedSeq {
            req: seq.req,
            step: seq.step,
            started: seq.started,
            first_token: seq.first_token,
            preempted_wait: seq.preempted_wait,
            since,
        })
    }

    /// Age-based priority promotion (`--age-promote`): a queued request
    /// that has waited `tau` seconds since arrival — or a suspended one,
    /// since suspension — moves up one class, two after `2·tau`.
    /// Promotion mutates the request's class: it admits, preempts, and
    /// completes as the promoted class from here on.
    fn promote_aged(&mut self) {
        let Some(tau) = self.age_promote else { return };
        let now = self.clock.now();
        for from in [Priority::Low, Priority::Normal] {
            let mut i = 0;
            while i < self.queues[from.idx()].len() {
                let waited = now - self.queues[from.idx()][i].at;
                let target = if waited >= 2.0 * tau {
                    Priority::High
                } else if waited >= tau {
                    Priority::Normal
                } else {
                    i += 1;
                    continue;
                };
                if target <= from {
                    i += 1;
                    continue;
                }
                let mut req = self.queues[from.idx()].remove(i).expect("indexed scan");
                req.priority = target;
                self.promotions += 1;
                self.rec
                    .emit(now, TraceEvent::Promote { request: req.id, to: target.idx() as u8 });
                self.queues[target.idx()].push_back(req);
            }
        }
        for (seq, since) in &mut self.suspended {
            let waited = now - *since;
            let target = if waited >= 2.0 * tau {
                Priority::High
            } else if waited >= tau {
                Priority::Normal
            } else {
                continue;
            };
            if target > seq.req.priority {
                seq.req.priority = target;
                self.promotions += 1;
                self.rec
                    .emit(now, TraceEvent::Promote { request: seq.req.id, to: target.idx() as u8 });
            }
        }
    }

    /// Earliest arrival time across the per-priority queues.
    fn next_arrival(&self) -> Option<f64> {
        self.queues
            .iter()
            .filter_map(|q| q.front().map(|r| r.at))
            .min_by(f64::total_cmp)
    }

    /// Fraction of `plan`'s experts resident in this replica's caches,
    /// taking the max with the planned residency of the queue tail so
    /// affinity works before the first decode warms anything.
    pub fn affinity_overlap(&self, plan: &PrefetchPlan) -> f64 {
        let resident = self.resident_overlap(plan);
        match &self.last_plan {
            Some(last) => resident.max(plan_overlap(plan, last)),
            None => resident,
        }
    }

    /// Fraction of `plan`'s experts currently resident (mean over layers,
    /// weighted by set size).
    pub fn resident_overlap(&self, plan: &PrefetchPlan) -> f64 {
        let mut num = 0usize;
        let mut den = 0usize;
        for (l, set) in plan.per_layer.iter().enumerate() {
            if l >= self.cache.layers.len() {
                break;
            }
            den += set.len();
            num += set.iter().filter(|&&e| self.cache.layers[l].contains(e)).count();
        }
        if den == 0 {
            0.0
        } else {
            num as f64 / den as f64
        }
    }

    /// Admit into free slots, highest priority class first; within a
    /// class, preempted sequences reattach (in suspension order) before
    /// new arrivals admit.  Static mode only opens admission once every
    /// slot has drained (the run-to-completion batch); continuous mode
    /// admits at every step.
    fn admit_ready(&mut self, max_batch: usize) {
        let open = match self.scheduler {
            SchedulerMode::Continuous => true,
            SchedulerMode::Static => self.in_flight.is_empty(),
        };
        if !open {
            return;
        }
        while self.in_flight.len() < max_batch.max(1) {
            let now = self.clock.now();
            // best suspended candidate (highest class, earliest suspension)
            let sus = self
                .suspended
                .iter()
                .enumerate()
                .max_by(|a, b| {
                    a.1 .0
                        .req
                        .priority
                        .cmp(&b.1 .0.req.priority)
                        .then(b.1 .1.total_cmp(&a.1 .1))
                })
                .map(|(i, (s, _))| (i, s.req.priority));
            // best ready queue class
            let ready = Priority::ALL
                .iter()
                .rev()
                .copied()
                .find(|p| matches!(self.queues[p.idx()].front(), Some(r) if r.at <= now));
            match (sus, ready) {
                // suspended wins ties: it has already made progress
                (Some((i, sp)), Some(rp)) if sp >= rp => self.reattach(i),
                (Some((i, _)), None) => self.reattach(i),
                (_, Some(p)) => {
                    let req = self.queues[p.idx()].pop_front().unwrap();
                    if req.disconnect {
                        // the client hung up while the request was still
                        // queued: drop it before it ever takes a slot
                        self.drop_disconnected(req);
                        continue;
                    }
                    if self.admission && !self.deadline_feasible(&req) {
                        self.reject(req);
                        continue;
                    }
                    self.admit_one(req);
                }
                (None, None) => break,
            }
        }
    }

    /// Compute-optimistic feasibility of `req`'s TTFT deadline if it were
    /// admitted right now: prefill steps at the configured chunk, no
    /// transfer stalls.  Optimistic on purpose — admission only turns a
    /// request away when even the best case already misses, so it never
    /// rejects a request the replica could have served in time.
    fn deadline_feasible(&self, req: &ClusterRequest) -> bool {
        let Some(d) = req.deadline else { return true };
        let per_step = self.spec.est_service_seconds(1, 0);
        let prefill_steps = req.prompt_tokens.div_ceil(self.prefill_chunk).max(1);
        self.clock.now() + prefill_steps as f64 * per_step <= d
    }

    /// Terminal-reject `req` (admission control).  No pin events: the
    /// request never reached a slot, so there is nothing to release.
    fn reject(&mut self, req: ClusterRequest) {
        let now = self.clock.now();
        self.rec.emit(now, TraceEvent::Reject { seq: req.id });
        self.completions.push(Completion {
            request_id: req.id,
            task: req.task,
            priority: req.priority,
            arrival: req.at,
            started: now,
            first_token: now,
            finished: now,
            output_tokens: 0,
            preempted_wait: 0.0,
            outcome: Outcome::Rejected,
            deadline: req.deadline,
        });
    }

    /// Terminal-cancel a request whose client disconnected while queued.
    /// No pin events: the request was never admitted.
    fn drop_disconnected(&mut self, req: ClusterRequest) {
        let now = self.clock.now();
        self.rec.emit(now, TraceEvent::Cancel { seq: req.id });
        self.completions.push(Completion {
            request_id: req.id,
            task: req.task,
            priority: req.priority,
            arrival: req.at,
            started: now,
            first_token: now,
            finished: now,
            output_tokens: 0,
            preempted_wait: 0.0,
            outcome: Outcome::Cancelled,
            deadline: req.deadline,
        });
    }

    /// Rebuild the union prefetch plan of the *live* in-flight set plus
    /// `plan` (in-flight plans come first, so capacity ties keep the warm
    /// working set) and top the cache up additively — the refresh never
    /// drops the planned working set of any live sequence (the pin
    /// ledger backs this), and warm residents outside it are evicted
    /// only under capacity pressure, in normal policy order.
    fn refresh_plan(&mut self, plan: &PrefetchPlan) {
        self.clock.advance(self.cost.predictor_time());
        let mut plans: Vec<&PrefetchPlan> = self.in_flight.iter().map(|a| &a.req.plan).collect();
        plans.push(plan);
        let caps = vec![self.spec.capacity; self.spec.n_layers];
        let union = PrefetchPlan::union_capped(&plans, &caps);
        for (l, set) in union.per_layer.iter().enumerate() {
            if set.is_empty() {
                continue;
            }
            // skip non-resident experts whose lookahead transfer is
            // already on the link — they arrive via the tracked
            // pipeline; re-issuing would double-pay the transfer.
            // (Resident in-flight experts stay in the target: the
            // union protects them from eviction and never re-loads
            // residents.)
            let want: Vec<usize> = set
                .iter()
                .copied()
                .filter(|&e| {
                    self.cache.layers[l].contains(e) || !self.pcie.in_flight_contains(l, e)
                })
                .collect();
            // tracked issue: residency is immediate (prefill_union
            // above), but the link entry keeps the stall/overlap
            // split exact and lets an evicted-then-remissed expert
            // catch its own transfer at the residual
            let out = self.cache.layer(l).prefill_union(&want);
            let t = self.clock.now();
            for &v in &out.evicted {
                self.rec.emit(t, TraceEvent::CacheEvict { layer: l as u32, expert: v as u32 });
            }
            for e in out.loaded {
                let snap = PcieSnap::of(&self.pcie.stats);
                self.pcie.prefetch_expert(&self.cost, &self.clock, l, e, self.spec.quant);
                self.rec.emit(
                    t,
                    TraceEvent::PrefetchIssued {
                        layer: l as u32,
                        expert: e as u32,
                        tier: self.spec.quant.idx() as u8,
                        delta: snap.delta(&self.pcie.stats),
                    },
                );
                self.rec.emit(t, TraceEvent::CacheInsert { layer: l as u32, expert: e as u32 });
            }
        }
    }

    /// Refresh the little store: per layer, rank experts by the routed
    /// assignment counts replayed so far and install little-tier copies
    /// of the hottest ones not already big-resident, up to the store's
    /// carved capacity.  Installs ride the untracked
    /// [`TransferEngine::prefetch_h2d`] path at the little tier and emit
    /// [`TraceEvent::LittleInstall`] carrying the byte delta; displaced
    /// little copies are dropped in place (derived read-only data — no
    /// D2H) with a [`TraceEvent::LittleEvict`].
    fn install_little_set(&mut self) {
        let Some(lt) = self.spec.little_tier else {
            return;
        };
        for l in 0..self.spec.n_layers {
            let cap = self.cache.layers[l].little_capacity();
            if cap == 0 {
                continue;
            }
            let mut ranked: Vec<usize> = (0..self.spec.n_experts).collect();
            ranked.sort_by_key(|&e| std::cmp::Reverse(self.route_counts[l][e]));
            ranked.retain(|&e| !self.cache.layers[l].contains(e));
            ranked.truncate(cap);
            for e in ranked {
                if self.cache.layers[l].has_little(e) {
                    continue;
                }
                let snap = PcieSnap::of(&self.pcie.stats);
                self.pcie.prefetch_h2d(&self.cost, &self.clock, lt);
                let t = self.clock.now();
                if let Some(evicted) = self.cache.layers[l].install_little(e) {
                    self.rec.emit(
                        t,
                        TraceEvent::LittleInstall {
                            layer: l as u32,
                            expert: e as u32,
                            tier: lt.idx() as u8,
                            delta: snap.delta(&self.pcie.stats),
                        },
                    );
                    if let Some(v) = evicted {
                        self.rec.emit(
                            t,
                            TraceEvent::LittleEvict { layer: l as u32, expert: v as u32 },
                        );
                    }
                }
            }
        }
    }

    /// Put one request into a decode slot: refresh the union prefetch
    /// plan and register its planned hot set in the cache's
    /// scheduler-owned pin ledger, so burst admissions and lookahead
    /// commits can never evict it while the sequence is live.
    fn admit_one(&mut self, req: ClusterRequest) {
        if self.spec.prefetch {
            self.refresh_plan(&req.plan);
        }
        self.install_little_set();
        self.cache.pin_set(req.id, &req.plan.per_layer);
        let now = self.clock.now();
        self.rec.emit(now, TraceEvent::RequestAdmit { seq: req.id });
        self.rec.emit(now, TraceEvent::PinSet { owner: req.id });
        self.in_flight.push(ActiveSeq {
            req,
            step: 0,
            started: now,
            first_token: now,
            preempted_wait: 0.0,
        });
    }

    /// Reattach suspended sequence `i`: accumulate its suspended time,
    /// re-run the admit-time plan refresh from its *memoized* plan, and
    /// re-register its pin-ledger entries.  The step cursor is untouched,
    /// so the replayed routing — and with it every completion metric —
    /// continues exactly where suspension stopped.
    fn reattach(&mut self, i: usize) {
        let (mut seq, since) = self.suspended.remove(i);
        seq.preempted_wait += (self.clock.now() - since).max(0.0);
        if self.spec.prefetch {
            self.refresh_plan(&seq.req.plan);
        }
        self.install_little_set();
        self.cache.pin_set(seq.req.id, &seq.req.plan.per_layer);
        let now = self.clock.now();
        self.rec.emit(now, TraceEvent::Resume { seq: seq.req.id });
        self.rec.emit(now, TraceEvent::PinSet { owner: seq.req.id });
        self.in_flight.push(seq);
    }

    /// Under [`PreemptPolicy::After`], suspend the lowest-priority (most
    /// recently started) in-flight sequence for every ready arrival of a
    /// strictly higher class that has out-waited the threshold.  The
    /// victim's pin-ledger entries release immediately — a suspended
    /// sequence no longer protects its warm set.  Continuous mode only.
    fn maybe_preempt(&mut self, max_batch: usize) {
        let Some(thresh) = self.preempt.threshold() else { return };
        if self.scheduler != SchedulerMode::Continuous {
            return;
        }
        let now = self.clock.now();
        for p in [Priority::High, Priority::Normal] {
            loop {
                if self.in_flight.len() < max_batch.max(1) {
                    return; // a slot is free: admission handles the waiter
                }
                let waited = match self.queues[p.idx()].front() {
                    Some(r) if r.at <= now => now - r.at,
                    _ => break,
                };
                if waited <= thresh {
                    break;
                }
                let victim = self
                    .in_flight
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| s.req.priority < p)
                    .min_by(|(_, a), (_, b)| {
                        a.req
                            .priority
                            .cmp(&b.req.priority)
                            .then(b.started.total_cmp(&a.started))
                    })
                    .map(|(i, _)| i);
                let Some(i) = victim else { break };
                let seq = self.in_flight.remove(i);
                self.cache.release(seq.req.id);
                self.rec.emit(now, TraceEvent::Suspend { seq: seq.req.id });
                self.rec.emit(now, TraceEvent::PinRelease { owner: seq.req.id });
                self.preemptions += 1;
                self.suspended.push((seq, now));
            }
        }
    }

    /// Tokens one sequence consumes this step: a prefilling sequence
    /// takes up to the chunk (clamped to the prompt boundary), a
    /// decoding one exactly one.
    fn tokens_this_step(&self, seq: &ActiveSeq) -> usize {
        let left = seq.req.prompt_tokens.saturating_sub(seq.step);
        if left > 0 {
            self.prefill_chunk.min(left)
        } else {
            1
        }
    }

    /// Advance the live batch one step: replay each sequence's routing —
    /// one decode token, or a whole prefill chunk — against the
    /// persistent caches, then charge the step's compute amortized over
    /// every token the step consumes (a prefill chunk's union expert set
    /// streams once — the Sarathi prefill term).  The clock advances
    /// *layer by layer*: misses at layer ℓ stall (a cold miss pays the
    /// full transfer, a miss whose lookahead prefetch is already on the
    /// link pays only the residual), then the next `lookahead` layers'
    /// upcoming expert sets are issued non-blocking, then layer ℓ's
    /// compute runs — hiding the issued transfers behind it.  The
    /// per-layer pin sets track every expert the step executes, so
    /// neither a peer's miss nor an arriving prefetch can evict one.
    /// Sequences whose trace ends retire immediately.
    ///
    /// The lookahead candidates come from the pre-drawn routing traces —
    /// the replica models a gate-ahead next-layer predictor (Huang et
    /// al.'s "Towards MoE Deployment" overlap) at the accuracy the trace
    /// implies; the artifact engine's honest equivalent is
    /// `predictor::predict_next_layer`.
    fn step_once(&mut self) {
        debug_assert!(!self.in_flight.is_empty());
        // expire fault windows and surface checksum failures: a corrupt
        // arrival is never committed — its reservation releases and the
        // consumer re-fetches via the normal miss path (all inert when
        // no faults were injected)
        self.refresh_health(self.clock.now());
        let now = self.clock.now();
        for (l, e) in self.pcie.take_corrupt(now) {
            self.rec.emit(now, TraceEvent::Corrupt { layer: l as u32, expert: e as u32 });
            self.cache.layer(l).unreserve(e);
        }
        let quant = self.spec.quant;
        let tier = quant.idx() as u8;
        let n_layers = self.spec.n_layers;
        let counts: Vec<usize> =
            self.in_flight.iter().map(|seq| self.tokens_this_step(seq)).collect();
        let t: usize = counts.iter().sum();
        if self.rec.enabled() {
            let t0 = self.clock.now();
            self.rec.emit(
                t0,
                TraceEvent::StepStart { tokens: t as u32, batch: counts.len() as u32 },
            );
            let rec = &mut self.rec;
            for (seq, &c) in self.in_flight.iter().zip(&counts) {
                if seq.step < seq.req.prompt_tokens {
                    rec.emit(t0, TraceEvent::PrefillChunk { seq: seq.req.id, tokens: c as u32 });
                }
            }
        }
        // per-layer distinct-expert working sets (the pin sets) and
        // assignment counts for the whole step, gathered once
        let mut pinned_by_layer: Vec<Vec<usize>> = vec![Vec::new(); n_layers];
        let mut assignments_by_layer: Vec<usize> = vec![0; n_layers];
        for (seq, &c) in self.in_flight.iter().zip(&counts) {
            for step in seq.step..seq.step + c {
                let Some(layers) = seq.req.routing.get(step) else { continue };
                for (l, experts) in layers.iter().enumerate().take(n_layers) {
                    for &e in experts {
                        assignments_by_layer[l] += 1;
                        self.total_assignments += 1;
                        self.route_counts[l][e] += 1;
                        if !pinned_by_layer[l].contains(&e) {
                            pinned_by_layer[l].push(e);
                        }
                    }
                }
            }
        }
        let depth = self.spec.lookahead;
        if depth > 0 {
            // one next-layer prediction consult per step
            self.clock.advance(self.cost.predictor_time());
        }
        for l in 0..n_layers {
            // land prefetches that arrived during earlier layers'
            // compute; commits never evict an expert this step executes
            let now = self.clock.now();
            for (tl, te) in self.pcie.drain_arrived(now) {
                let out = self.pcie.commit_arrival(
                    &mut self.cache.layers[tl],
                    &self.cost,
                    quant,
                    te,
                    &pinned_by_layer[tl],
                );
                if out.resident {
                    self.rec.emit(
                        now,
                        TraceEvent::TransferLanded { layer: tl as u32, expert: te as u32, tier },
                    );
                    if out.loaded {
                        self.rec.emit(
                            now,
                            TraceEvent::CacheInsert { layer: tl as u32, expert: te as u32 },
                        );
                        if let Some(v) = out.evicted {
                            self.rec.emit(
                                now,
                                TraceEvent::CacheEvict { layer: tl as u32, expert: v as u32 },
                            );
                        }
                    }
                } else {
                    // every resident pinned: the arrival stays in
                    // staging, claimable at zero residual
                    self.rec.emit(
                        now,
                        TraceEvent::PinProtected { layer: tl as u32, expert: te as u32 },
                    );
                    self.pcie.track_landed(tl, te, now);
                }
            }
            // resolve residency: hits are free, an in-flight prefetch
            // pays the residual, cold misses demand-transfer and stall —
            // unless the big-little fallback serves the miss degraded
            // from a resident little copy at zero stall
            let mut degraded_assigns = 0usize;
            let mut degraded_set: Vec<usize> = Vec::new();
            for (seq, &c) in self.in_flight.iter().zip(&counts) {
                for step in seq.step..seq.step + c {
                    let Some(experts) = seq.req.routing.get(step).and_then(|s| s.get(l)) else {
                        continue;
                    };
                    for &e in experts {
                        let hit = self.cache.layers[l].request(e);
                        if hit {
                            continue;
                        }
                        let (l32, e32) = (l as u32, e as u32);
                        if let Some(lt) = self.spec.little_tier {
                            if self.cache.layers[l].has_little(e) {
                                let now = self.clock.now();
                                let wait = self.pcie.residual_of(l, e, now).unwrap_or_else(|| {
                                    self.pcie.demand_estimate(&self.cost, now, quant)
                                });
                                if wait > self.fallback_threshold() {
                                    self.degraded_execs += 1;
                                    degraded_assigns += 1;
                                    if !degraded_set.contains(&e) {
                                        degraded_set.push(e);
                                    }
                                    self.rec.emit(
                                        now,
                                        TraceEvent::DegradedExec {
                                            layer: l32,
                                            expert: e32,
                                            tier: lt.idx() as u8,
                                        },
                                    );
                                    continue;
                                }
                            }
                        }
                        let snap = PcieSnap::of(&self.pcie.stats);
                        if self.pcie.wait_for(l, e, &mut self.clock).is_some() {
                            // the claim consumed the transfer's one
                            // stall-free use; commit lands it whenever
                            // the pin set allows
                            let now = self.clock.now();
                            self.rec.emit(
                                now,
                                TraceEvent::DemandStall {
                                    layer: l32,
                                    expert: e32,
                                    tier,
                                    residual: true,
                                    delta: snap.delta(&self.pcie.stats),
                                },
                            );
                            let out = self.pcie.commit_arrival(
                                &mut self.cache.layers[l],
                                &self.cost,
                                quant,
                                e,
                                &pinned_by_layer[l],
                            );
                            // the claim consumed the in-flight entry
                            // either way, so the transfer always lands
                            self.rec.emit(
                                now,
                                TraceEvent::TransferLanded { layer: l32, expert: e32, tier },
                            );
                            if out.loaded {
                                self.rec.emit(
                                    now,
                                    TraceEvent::CacheInsert { layer: l32, expert: e32 },
                                );
                                if let Some(v) = out.evicted {
                                    self.rec.emit(
                                        now,
                                        TraceEvent::CacheEvict { layer: l32, expert: v as u32 },
                                    );
                                }
                            } else if !out.resident {
                                self.rec.emit(
                                    now,
                                    TraceEvent::PinProtected { layer: l32, expert: e32 },
                                );
                            }
                            continue;
                        }
                        self.pcie.demand_h2d(&self.cost, &mut self.clock, quant);
                        self.rec.emit(
                            self.clock.now(),
                            TraceEvent::DemandStall {
                                layer: l32,
                                expert: e32,
                                tier,
                                residual: false,
                                delta: snap.delta(&self.pcie.stats),
                            },
                        );
                        let evicted = self.cache.layers[l].insert(e, &pinned_by_layer[l]);
                        if evicted.is_some() {
                            self.pcie.evict_d2h(&self.cost, quant);
                        }
                        if self.rec.enabled() {
                            let now = self.clock.now();
                            if self.cache.layers[l].contains(e) {
                                self.rec.emit(
                                    now,
                                    TraceEvent::CacheInsert { layer: l32, expert: e32 },
                                );
                                if let Some(v) = evicted {
                                    self.rec.emit(
                                        now,
                                        TraceEvent::CacheEvict { layer: l32, expert: v as u32 },
                                    );
                                }
                            } else {
                                self.rec.emit(
                                    now,
                                    TraceEvent::PinProtected { layer: l32, expert: e32 },
                                );
                            }
                        }
                    }
                }
            }
            // layer-ahead pipeline: issue the next `depth` layers'
            // working sets non-blocking, before this layer's compute, so
            // the transfers hide behind it
            for nl in l + 1..=(l + depth).min(n_layers.saturating_sub(1)) {
                for &e in &pinned_by_layer[nl] {
                    if self.cache.layers[nl].contains(e) || self.pcie.in_flight_contains(nl, e) {
                        continue;
                    }
                    if !self.cache.layer(nl).reserve(e) {
                        break; // reservations saturated this layer
                    }
                    let snap = PcieSnap::of(&self.pcie.stats);
                    self.pcie.prefetch_expert(&self.cost, &self.clock, nl, e, quant);
                    self.rec.emit(
                        self.clock.now(),
                        TraceEvent::PrefetchIssued {
                            layer: nl as u32,
                            expert: e as u32,
                            tier,
                            delta: snap.delta(&self.pcie.stats),
                        },
                    );
                }
            }
            // this layer's compute: attention over every consumed token
            // plus grouped execution of the step's distinct working set.
            // Degraded assignments execute from the little-tier copies
            // (cheaper weight streaming, dequant overhead included); the
            // rest stream the full-tier working set.
            let exec = if pinned_by_layer[l].is_empty() {
                0.0
            } else if degraded_assigns == 0 {
                self.cost.expert_exec_time(pinned_by_layer[l].len(), assignments_by_layer[l], quant)
            } else {
                let lt = self.spec.little_tier.expect("degraded exec implies a little tier");
                let big_assigns = assignments_by_layer[l] - degraded_assigns;
                let mut exec =
                    self.cost.expert_exec_time(degraded_set.len(), degraded_assigns, lt);
                if big_assigns > 0 {
                    let big_unique =
                        pinned_by_layer[l].len().saturating_sub(degraded_set.len()).max(1);
                    exec += self.cost.expert_exec_time(big_unique, big_assigns, quant);
                }
                exec
            };
            // `* 1.0` is bit-exact, so a fault-free run pays nothing
            self.clock.advance((self.cost.attn_time(t) + exec) * self.slow_factor);
        }
        self.clock.advance(self.cost.head_time(t) * self.slow_factor);
        self.cache.token_tick();

        // advance cursors; retire finished sequences immediately — their
        // slots (and their share of compute and cache traffic) free now.
        // `counts` is indexed in the original in-flight order, which the
        // removal-by-index walk preserves.
        let now = self.clock.now();
        self.rec.emit(now, TraceEvent::StepEnd { tokens: t as u32, batch: counts.len() as u32 });
        let mut i = 0;
        for &c in &counts {
            let seq = &mut self.in_flight[i];
            let before = seq.step;
            seq.step += c;
            let first_at = seq.req.prompt_tokens.max(1).min(seq.req.routing.len().max(1));
            if before < first_at && seq.step >= first_at {
                seq.first_token = now;
            }
            let produced =
                seq.step.saturating_sub(seq.req.prompt_tokens).min(seq.req.max_output);
            let hangup = seq.req.cancel_after.is_some_and(|n| produced >= n);
            if seq.step >= seq.req.routing.len() {
                // natural completion (wins a same-step tie with a hangup:
                // the client got its full output)
                let seq = self.in_flight.remove(i);
                self.cache.release(seq.req.id);
                self.rec.emit(
                    now,
                    TraceEvent::RequestRetire {
                        seq: seq.req.id,
                        output_tokens: seq.req.max_output as u32,
                    },
                );
                self.rec.emit(now, TraceEvent::PinRelease { owner: seq.req.id });
                self.completions.push(Completion {
                    request_id: seq.req.id,
                    task: seq.req.task,
                    priority: seq.req.priority,
                    arrival: seq.req.at,
                    started: seq.started,
                    first_token: seq.first_token,
                    finished: now,
                    output_tokens: seq.req.max_output,
                    preempted_wait: seq.preempted_wait,
                    outcome: Outcome::Completed,
                    deadline: seq.req.deadline,
                });
            } else if hangup {
                // cancel-after-N: the client hung up mid-decode — the
                // one-way suspend: slot and pin-ledger entries reclaim
                // now, and the completion reports the partial output
                let seq = self.in_flight.remove(i);
                self.cache.release(seq.req.id);
                self.rec.emit(now, TraceEvent::Cancel { seq: seq.req.id });
                self.rec.emit(now, TraceEvent::PinRelease { owner: seq.req.id });
                self.completions.push(Completion {
                    request_id: seq.req.id,
                    task: seq.req.task,
                    priority: seq.req.priority,
                    arrival: seq.req.at,
                    started: seq.started,
                    first_token: seq.first_token,
                    finished: now,
                    output_tokens: produced,
                    preempted_wait: seq.preempted_wait,
                    outcome: Outcome::Cancelled,
                    deadline: seq.req.deadline,
                });
            } else {
                i += 1;
            }
        }
        // a recovering replica's first served step proves it out
        if self.health == Health::Recovering {
            self.health = Health::Healthy;
        }
    }

    /// Preempt if allowed, admit what's ready, and advance exactly one
    /// token step (fast-forwarding an idle clock to the next queued
    /// arrival first — suspended sequences reattach without waiting).
    pub fn run_one_step(&mut self, max_batch: usize) {
        if self.in_flight.is_empty() && self.suspended.is_empty() {
            match self.next_arrival() {
                None => return,
                Some(at) if at > self.clock.now() => {
                    let dt = at - self.clock.now();
                    self.clock.advance(dt);
                }
                _ => {}
            }
        }
        let t0 = self.clock.now();
        // promote before preemption checks so a freshly aged-up class is
        // what both preemption and admission see this step
        self.promote_aged();
        self.maybe_preempt(max_batch);
        self.admit_ready(max_batch);
        if self.in_flight.is_empty() {
            return;
        }
        self.step_once();
        self.busy_seconds += self.clock.now() - t0;
    }

    /// Serve until this replica's clock reaches `horizon` (a token step
    /// started before the horizon completes, so the clock may overshoot
    /// by one step — in-flight sequences stay resumable across calls).
    pub fn run_until(&mut self, horizon: f64, max_batch: usize) {
        while self.has_work() {
            if self.in_flight.is_empty() && self.suspended.is_empty() {
                // next possible start is the front arrival
                let at = self.next_arrival().unwrap_or(f64::INFINITY);
                if self.clock.now().max(at) >= horizon {
                    break;
                }
            } else if self.clock.now() >= horizon {
                break;
            }
            self.run_one_step(max_batch);
        }
    }
}

/// Mean per-layer overlap between two prefetch plans (size-weighted).
fn plan_overlap(a: &PrefetchPlan, b: &PrefetchPlan) -> f64 {
    let mut num = 0usize;
    let mut den = 0usize;
    for (l, set) in a.per_layer.iter().enumerate() {
        let other = match b.per_layer.get(l) {
            Some(o) => o,
            None => continue,
        };
        den += set.len();
        num += set.iter().filter(|e| other.contains(*e)).count();
    }
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

#[cfg(test)]
mod tests {
    use super::super::workload::{
        generate, OutputLen, PriorityMix, StreamMix, TaskProfile, WorkloadSpec,
    };
    use super::*;
    use crate::coordinator::workload::Arrival;
    use crate::util::rng::Rng;

    fn spec() -> ReplicaSpec {
        let mut s = ReplicaSpec::olmoe(GpuSpec::h100());
        // small model for fast unit tests
        s.n_layers = 4;
        s.n_experts = 16;
        s.top_k = 2;
        s.capacity = 4;
        s
    }

    fn requests(n: usize, tasks: usize, seed: u64, s: &ReplicaSpec) -> Vec<ClusterRequest> {
        let profiles = TaskProfile::synthetic(tasks, s.n_layers, s.n_experts, s.capacity, 0.9);
        let wl = WorkloadSpec {
            n_requests: n,
            arrival: Arrival::Burst,
            prompt_tokens: 2,
            output: OutputLen::Fixed(4),
            balanced_tasks: false,
            priorities: PriorityMix::none(),
            stream: StreamMix::none(),
            seed,
        };
        generate(&wl, &profiles, s.n_layers, s.n_experts, s.top_k)
    }

    /// A hand-built request with chosen prompt/output lengths (slot-reuse,
    /// early-retirement and chunked-prefill tests need controlled shapes).
    fn req_shaped(
        id: u64,
        prompt_tokens: usize,
        out: usize,
        s: &ReplicaSpec,
        seed: u64,
    ) -> ClusterRequest {
        let profiles = TaskProfile::synthetic(1, s.n_layers, s.n_experts, s.capacity, 0.9);
        let mut rng = Rng::new(seed);
        let routing = (0..prompt_tokens + out)
            .map(|_| {
                (0..s.n_layers)
                    .map(|l| profiles[0].draw(l, s.top_k, s.n_experts, &mut rng))
                    .collect()
            })
            .collect();
        ClusterRequest {
            id,
            task: 0,
            priority: Priority::Normal,
            at: 0.0,
            prompt_tokens,
            max_output: out,
            deadline: None,
            cancel_after: None,
            disconnect: false,
            routing,
            plan: profiles[0].plan(),
        }
    }

    /// `req_shaped` with an explicit priority class.
    fn req_prio(
        id: u64,
        prompt_tokens: usize,
        out: usize,
        priority: Priority,
        s: &ReplicaSpec,
        seed: u64,
    ) -> ClusterRequest {
        let mut r = req_shaped(id, prompt_tokens, out, s, seed);
        r.priority = priority;
        r
    }

    /// A one-prompt-token request with a chosen output length.
    fn req_with_len(id: u64, out: usize, s: &ReplicaSpec, seed: u64) -> ClusterRequest {
        req_shaped(id, 1, out, s, seed)
    }

    #[test]
    fn replica_serves_all_queued_requests() {
        let s = spec();
        let mut r = Replica::new(0, s.clone(), SchedulerMode::Continuous);
        let reqs = requests(6, 2, 3, &s);
        // exact routed-request count: retired sequences must contribute
        // nothing beyond their own traces
        let expected_cache_requests: u64 = reqs
            .iter()
            .map(|q| q.routing.iter().flatten().map(|e| e.len() as u64).sum::<u64>())
            .sum();
        for req in reqs {
            r.enqueue(req);
        }
        assert_eq!(r.queue_depth(), 6);
        assert_eq!(r.peak_queue_depth, 6);
        r.run_until(f64::INFINITY, 2);
        assert_eq!(r.queue_depth(), 0);
        assert_eq!(r.slots_in_use(), 0);
        assert_eq!(r.completions.len(), 6);
        assert!(r.clock.now() > 0.0);
        assert!(r.busy_seconds > 0.0);
        let stats = r.cache.total_stats();
        assert_eq!(stats.requests(), stats.hits + stats.misses);
        assert_eq!(
            stats.requests(),
            expected_cache_requests,
            "a retired sequence kept issuing cache requests"
        );
        // monotone per-request timeline
        for c in &r.completions {
            assert!(c.finished >= c.started);
            assert!(c.first_token >= c.started && c.first_token <= c.finished);
            assert!(c.queue_wait() >= 0.0);
            assert!(c.ttft() > 0.0);
            assert!(c.latency() > 0.0);
        }
    }

    /// Early retirement re-admits queued work mid-flight: with slots
    /// {long, short} and a third request queued, the continuous scheduler
    /// starts the third inside the long sequence's window, while the
    /// static scheduler waits for the whole batch to drain.
    #[test]
    fn continuous_reuses_slot_freed_by_early_retirement() {
        let s = spec();
        let reqs = || {
            vec![
                req_with_len(0, 12, &s, 1),
                req_with_len(1, 3, &s, 2),
                req_with_len(2, 3, &s, 3),
            ]
        };

        let mut cont = Replica::new(0, s.clone(), SchedulerMode::Continuous);
        for q in reqs() {
            cont.enqueue(q);
        }
        cont.run_until(f64::INFINITY, 2);
        let long_fin = cont.completions.iter().find(|c| c.request_id == 0).unwrap().finished;
        let third = cont.completions.iter().find(|c| c.request_id == 2).unwrap();
        assert!(
            third.started < long_fin,
            "continuous: freed slot must re-admit mid-flight ({} >= {})",
            third.started,
            long_fin
        );

        let mut stat = Replica::new(0, s.clone(), SchedulerMode::Static);
        for q in reqs() {
            stat.enqueue(q);
        }
        stat.run_until(f64::INFINITY, 2);
        let long_fin = stat.completions.iter().find(|c| c.request_id == 0).unwrap().finished;
        let third = stat.completions.iter().find(|c| c.request_id == 2).unwrap();
        assert!(
            third.started >= long_fin,
            "static: a new batch must wait for the previous one to drain"
        );
        // identical traffic, so continuous finishes the set no later
        assert!(
            cont.clock.now() <= stat.clock.now() + 1e-9,
            "continuous makespan {} vs static {}",
            cont.clock.now(),
            stat.clock.now()
        );
    }

    #[test]
    fn horizon_bounds_steps_and_work_is_resumable() {
        let s = spec();
        let mut r = Replica::new(0, s.clone(), SchedulerMode::Continuous);
        for req in requests(8, 2, 4, &s) {
            r.enqueue(req);
        }
        // a tiny horizon runs exactly the one step that started before it
        r.run_until(1e-9, 4);
        assert!(r.clock.now() > 0.0, "a step starting before the horizon must run");
        assert!(r.completions.is_empty(), "one step cannot finish a 6-step request");
        assert_eq!(r.slots_in_use(), 4, "admission fills the slots before stepping");
        r.run_until(f64::INFINITY, 4);
        assert_eq!(r.completions.len(), 8);
    }

    #[test]
    fn same_task_traffic_warms_cache() {
        let s = spec();
        // task-pure stream on one replica: later requests should mostly hit
        let mut r = Replica::new(0, s.clone(), SchedulerMode::Continuous);
        let reqs: Vec<ClusterRequest> =
            requests(12, 1, 5, &s).into_iter().filter(|q| q.task == 0).collect();
        assert!(reqs.len() >= 8);
        for req in reqs {
            r.enqueue(req);
        }
        r.run_until(f64::INFINITY, 1);
        let stats = r.cache.total_stats();
        assert!(
            stats.hit_rate() > 0.5,
            "persistent cache should be hit-bound on task-pure traffic: {}",
            stats.hit_rate()
        );
    }

    #[test]
    fn affinity_overlap_sees_planned_residency_before_decode() {
        let s = spec();
        let mut r = Replica::new(0, s.clone(), SchedulerMode::Continuous);
        let profiles = TaskProfile::synthetic(2, s.n_layers, s.n_experts, s.capacity, 0.9);
        // cold: no residency, no queue
        assert_eq!(r.affinity_overlap(&profiles[0].plan()), 0.0);
        let reqs = requests(4, 2, 9, &s);
        let task0 = reqs.iter().find(|q| q.task == 0).cloned();
        if let Some(q) = task0 {
            r.enqueue(q);
            // planned residency: same task scores high, other task low
            let same = r.affinity_overlap(&profiles[0].plan());
            let other = r.affinity_overlap(&profiles[1].plan());
            assert!(same > 0.99, "same-task planned overlap {same}");
            assert!(other < same, "other-task overlap {other} >= {same}");
        }
    }

    /// Chunked prefill consumes the same routed traffic in fewer,
    /// cheaper-per-prompt-token steps: TTFT falls, while cache request
    /// totals and output lengths are identical to token-at-a-time.
    #[test]
    fn chunked_prefill_cuts_ttft_on_identical_traffic() {
        let s = spec();
        let run = |chunk: usize| {
            let mut r =
                Replica::new(0, s.clone(), SchedulerMode::Continuous).with_prefill_chunk(chunk);
            r.enqueue(req_shaped(0, 48, 4, &s, 7));
            r.run_until(f64::INFINITY, 2);
            r
        };
        let r1 = run(1);
        let r8 = run(8);
        assert_eq!(r1.completions.len(), 1);
        assert_eq!(r8.completions.len(), 1);
        let (c1, c8) = (&r1.completions[0], &r8.completions[0]);
        assert!(
            c8.ttft() < c1.ttft(),
            "chunk=8 ttft {:.4}s >= chunk=1 ttft {:.4}s",
            c8.ttft(),
            c1.ttft()
        );
        assert!(c8.latency() < c1.latency());
        assert_eq!(c1.output_tokens, c8.output_tokens);
        // same pre-drawn routing replayed → identical cache lookup totals
        assert_eq!(r1.cache.total_stats().requests(), r8.cache.total_stats().requests());
    }

    /// A chunk never crosses the prompt boundary: the step that consumes
    /// the last prompt token lands the first output token, and decode
    /// still emits exactly one token per step afterwards.
    #[test]
    fn chunk_clamps_to_prompt_boundary() {
        let s = spec();
        let mut r = Replica::new(0, s.clone(), SchedulerMode::Continuous).with_prefill_chunk(32);
        // 5-token prompt (not a multiple of the chunk), 3 output tokens
        r.enqueue(req_shaped(0, 5, 3, &s, 11));
        let mut steps = 0;
        while r.has_work() {
            r.run_one_step(1);
            steps += 1;
            assert!(steps < 100, "replica failed to drain");
        }
        // 1 prefill step (chunk clamps 32 → 5) + 3 decode steps
        assert_eq!(steps, 4);
        let c = &r.completions[0];
        assert!(c.first_token > c.started && c.first_token < c.finished);
    }

    #[test]
    fn est_service_positive_and_scales() {
        let s = ReplicaSpec::olmoe(GpuSpec::h100());
        let a = s.est_service_seconds(8, 16);
        let b = s.est_service_seconds(8, 32);
        assert!(a > 0.0);
        assert!(b > a);
        // paper-scale OLMoE decodes tens of ms per token (Table 1 regime)
        let per_tok = a / 24.0;
        assert!((0.001..1.0).contains(&per_tok), "per-token {per_tok}");
    }

    #[test]
    fn vram_budget_derives_capacity() {
        let s = ReplicaSpec::olmoe(GpuSpec::h100());
        assert!((2..=64).contains(&s.capacity), "capacity {}", s.capacity);
        let big = ReplicaSpec::from_vram_gb(GpuSpec::h100(), s.dims, 400.0);
        assert_eq!(big.capacity, s.dims.n_experts);
    }

    // --------------------------------------------------- priority/preemption

    /// One slot held by a long Low decode, a High arriving shortly after:
    /// with preemption the High's TTFT is bounded near the threshold and
    /// the Low resumes to the same completion accounting as an
    /// uninterrupted run (same output tokens; only timing shifts).
    #[test]
    fn preemption_bounds_high_ttft_and_victim_resumes() {
        let s = spec();
        // a solo decode step's duration bounds the preemption detection lag
        let step_t = s.est_service_seconds(1, 40) / 41.0;
        let arrive_at = 4.0 * step_t;
        let thresh = 2.0 * step_t;
        let build = |preempt: PreemptPolicy| {
            let mut r = Replica::new(0, s.clone(), SchedulerMode::Continuous)
                .with_preempt(preempt);
            r.enqueue(req_prio(0, 1, 40, Priority::Low, &s, 1));
            let mut high = req_prio(1, 1, 3, Priority::High, &s, 2);
            high.at = arrive_at;
            r.enqueue(high);
            r.run_until(f64::INFINITY, 1);
            r
        };
        let off = build(PreemptPolicy::Off);
        let on = build(PreemptPolicy::After(thresh));
        assert_eq!(off.preemptions, 0);
        assert_eq!(on.preemptions, 1, "the High must have preempted the Low");
        let high_of = |r: &Replica| {
            r.completions.iter().find(|c| c.request_id == 1).cloned().unwrap()
        };
        let (h_off, h_on) = (high_of(&off), high_of(&on));
        assert!(
            h_on.ttft() < h_off.ttft(),
            "preemption must cut High TTFT: {} vs {}",
            h_on.ttft(),
            h_off.ttft()
        );
        // without preemption the High waits out the whole Low decode
        assert!(h_off.ttft() > 30.0 * step_t);
        // with preemption it starts within threshold + a couple of steps
        // (one in-flight step finishes before the boundary check)
        assert!(h_on.ttft() <= thresh + 4.0 * step_t + 1e-9, "ttft {}", h_on.ttft());
        // the victim resumed and completed with identical token accounting
        let low_of = |r: &Replica| {
            r.completions.iter().find(|c| c.request_id == 0).cloned().unwrap()
        };
        let (l_off, l_on) = (low_of(&off), low_of(&on));
        assert_eq!(l_off.output_tokens, l_on.output_tokens);
        assert!(l_on.preempted_wait > 0.0, "suspension time must be reported");
        assert_eq!(l_off.preempted_wait, 0.0);
        assert!(l_on.finished > l_off.finished, "the victim pays the suspension");
        // identical routed work overall: same cache request totals
        assert_eq!(
            off.cache.total_stats().requests(),
            on.cache.total_stats().requests(),
            "suspension must not add or drop routed traffic"
        );
    }

    /// Suspended state survives an idle queue: with nothing else to run,
    /// the replica reattaches the victim rather than deadlocking.
    #[test]
    fn suspended_sequence_always_reattaches() {
        let s = spec();
        let step_t = s.est_service_seconds(1, 20) / 21.0;
        let mut r = Replica::new(0, s.clone(), SchedulerMode::Continuous)
            .with_preempt(PreemptPolicy::After(0.0));
        r.enqueue(req_prio(0, 1, 20, Priority::Low, &s, 3));
        let mut high = req_prio(1, 1, 2, Priority::High, &s, 4);
        high.at = 2.0 * step_t;
        r.enqueue(high);
        let mut steps = 0;
        while r.has_work() {
            r.run_one_step(1);
            steps += 1;
            assert!(steps < 200, "replica failed to drain suspended work");
        }
        assert_eq!(r.completions.len(), 2);
        assert_eq!(r.suspended_len(), 0);
        assert!(r.preemptions >= 1);
    }

    // ------------------------------------------------ streaming front-end

    /// Cancel-after-N mid-decode: the slot and pin-ledger entries reclaim
    /// the moment the hang-up step ends (the queued request admits into
    /// the freed slot), the completion reports the partial output, and
    /// the trace's pin conservation audit balances to zero.
    #[test]
    fn cancel_after_frees_slot_and_balances_pins() {
        let s = spec();
        let mut r =
            Replica::new(0, s.clone(), SchedulerMode::Continuous).with_trace(true);
        let mut early = req_shaped(0, 1, 40, &s, 1);
        early.cancel_after = Some(2);
        r.enqueue(early);
        r.enqueue(req_shaped(1, 1, 3, &s, 2));
        r.run_until(f64::INFINITY, 1);
        assert_eq!(r.completions.len(), 2);
        let c0 = r.completions.iter().find(|c| c.request_id == 0).unwrap();
        assert_eq!(c0.outcome, Outcome::Cancelled);
        assert_eq!(c0.output_tokens, 2, "partial output up to the hang-up");
        let c1 = r.completions.iter().find(|c| c.request_id == 1).unwrap();
        assert_eq!(c1.outcome, Outcome::Completed);
        assert!(
            c1.started < c0.started + s.est_service_seconds(1, 40),
            "the freed slot must re-admit well before the cancelled decode would have ended"
        );
        assert_eq!(r.slots_in_use(), 0);
        let tr = r.take_trace().expect("tracing was on");
        tr.audit_pins(0).expect("a cancelled sequence must leak zero pins");
    }

    /// A queue-time disconnect never takes a slot: it terminal-cancels
    /// with zero output and the replica's caches see only the survivor's
    /// traffic.
    #[test]
    fn queued_disconnect_never_admits() {
        let s = spec();
        let mut r = Replica::new(0, s.clone(), SchedulerMode::Continuous);
        let mut gone = req_shaped(0, 2, 4, &s, 3);
        gone.disconnect = true;
        let stay = req_shaped(1, 2, 4, &s, 4);
        let expected: u64 =
            stay.routing.iter().flatten().map(|e| e.len() as u64).sum();
        r.enqueue(gone);
        r.enqueue(stay);
        r.run_until(f64::INFINITY, 2);
        let c0 = r.completions.iter().find(|c| c.request_id == 0).unwrap();
        assert_eq!(c0.outcome, Outcome::Cancelled);
        assert_eq!(c0.output_tokens, 0);
        assert!(!c0.attained());
        let stats = r.cache.total_stats();
        assert_eq!(stats.requests(), expected, "the disconnected request must not decode");
    }

    /// Admission control turns away a deadline the compute-optimistic
    /// estimate already misses, and leaves feasible deadlines alone.
    #[test]
    fn admission_rejects_only_hopeless_deadlines() {
        let s = spec();
        let mut r =
            Replica::new(0, s.clone(), SchedulerMode::Continuous).with_admission(true);
        let mut hopeless = req_shaped(0, 4, 4, &s, 5);
        hopeless.deadline = Some(1e-12);
        let mut feasible = req_shaped(1, 4, 4, &s, 6);
        feasible.deadline = Some(1e9);
        r.enqueue(hopeless);
        r.enqueue(feasible);
        r.run_until(f64::INFINITY, 2);
        let c0 = r.completions.iter().find(|c| c.request_id == 0).unwrap();
        assert_eq!(c0.outcome, Outcome::Rejected);
        assert_eq!(c0.output_tokens, 0);
        let c1 = r.completions.iter().find(|c| c.request_id == 1).unwrap();
        assert_eq!(c1.outcome, Outcome::Completed);
        assert!(c1.attained(), "a met deadline counts toward goodput");
        // admission off: the hopeless request is served anyway (and misses)
        let mut off = Replica::new(0, s.clone(), SchedulerMode::Continuous);
        let mut hopeless = req_shaped(0, 4, 4, &s, 5);
        hopeless.deadline = Some(1e-12);
        off.enqueue(hopeless);
        off.run_until(f64::INFINITY, 2);
        let c = &off.completions[0];
        assert_eq!(c.outcome, Outcome::Completed);
        assert!(!c.attained(), "a missed deadline must not count toward goodput");
    }

    // ------------------------------------------------------ fault injection

    /// A crash reclaims every live and queued request exactly once, wipes
    /// GPU state, rides out the outage on its own clock, and leaves the
    /// pin conservation audit balanced (in-flight pins release at the
    /// crash; suspended ones already released at suspension).
    #[test]
    fn crash_reclaims_everything_and_balances_pins() {
        let s = spec();
        let mut r = Replica::new(0, s.clone(), SchedulerMode::Continuous).with_trace(true);
        for (i, seed) in [1u64, 2, 3].into_iter().enumerate() {
            r.enqueue(req_shaped(i as u64, 1, 40, &s, seed));
        }
        for _ in 0..3 {
            r.run_one_step(2);
        }
        assert_eq!(r.slots_in_use(), 2);
        assert_eq!(r.queue_depth(), 1);
        let down_until = r.clock.now() + 1.0;
        let reclaimed = r.crash(down_until);
        assert_eq!(reclaimed.len(), 3, "every live and queued request is reclaimed");
        assert_eq!(r.health(), Health::Down);
        assert!(!r.health().dispatchable());
        assert!(!r.has_work());
        assert!(r.clock.now() >= down_until, "the clock rides out the outage");
        assert!(r.completions.is_empty(), "a crash is not a terminal outcome");
        // GPU state is gone: planned and resident affinity both read cold
        let profiles = TaskProfile::synthetic(1, s.n_layers, s.n_experts, s.capacity, 0.9);
        assert_eq!(r.affinity_overlap(&profiles[0].plan()), 0.0);
        assert!(r.crash(down_until).is_empty(), "a second crash has nothing to reclaim");
        r.refresh_health(down_until);
        assert_eq!(r.health(), Health::Recovering);
        assert!(r.health().dispatchable());
        r.take_trace().unwrap().audit_pins(0).expect("pins balance across the crash");
    }

    /// An expired brownout window is fully inert — the first step resets
    /// the multiplier and the run is bit-identical to fault-free — while
    /// a live window strictly slows compute and reads `Degraded`.
    #[test]
    fn brownout_slows_compute_and_expires_cleanly() {
        let s = spec();
        let run = |brownout: Option<(f64, f64)>| {
            let mut r = Replica::new(0, s.clone(), SchedulerMode::Continuous);
            if let Some((f, until)) = brownout {
                r.set_brownout(f, until);
            }
            r.enqueue(req_shaped(0, 1, 8, &s, 7));
            r.run_until(f64::INFINITY, 1);
            r
        };
        let clean = run(None);
        let slowed = run(Some((4.0, f64::INFINITY)));
        let expired = run(Some((4.0, 0.0)));
        assert!(slowed.clock.now() > clean.clock.now(), "a live brownout must cost time");
        assert_eq!(slowed.health(), Health::Degraded);
        assert_eq!(
            expired.clock.now().to_bits(),
            clean.clock.now().to_bits(),
            "an expired window must be bit-identical to fault-free"
        );
        assert_eq!(expired.health(), Health::Healthy);
    }

    /// Mid-flight migration preserves the decode exactly: the adopter
    /// resumes the step cursors and both requests complete with full
    /// output, with both lanes' pin ledgers balanced.
    #[test]
    fn migrated_sequences_complete_on_the_adopter() {
        let s = spec();
        let mut a = Replica::new(0, s.clone(), SchedulerMode::Continuous).with_trace(true);
        let mut b = Replica::new(1, s.clone(), SchedulerMode::Continuous).with_trace(true);
        a.enqueue(req_shaped(0, 1, 24, &s, 5));
        a.enqueue(req_shaped(1, 1, 24, &s, 6));
        for _ in 0..4 {
            a.run_one_step(2);
        }
        assert_eq!(a.slots_in_use(), 2);
        let moved = a.extract_live();
        assert_eq!(moved.len(), 2);
        assert!(!a.has_work());
        let t = a.clock.now();
        for m in moved {
            b.adopt(m, t);
        }
        assert_eq!(b.suspended_len(), 2);
        b.run_until(f64::INFINITY, 2);
        assert_eq!(b.completions.len(), 2);
        for c in &b.completions {
            assert_eq!(c.outcome, Outcome::Completed);
            assert_eq!(c.output_tokens, 24, "migration must not drop decoded tokens");
            assert!(c.preempted_wait >= 0.0);
            assert!(c.finished >= t, "the adopter cannot finish in its own past");
        }
        a.take_trace().unwrap().audit_pins(0).expect("donor pins balance");
        b.take_trace().unwrap().audit_pins(0).expect("adopter pins balance");
    }

    /// The steal-candidate accessors pick exactly what the scan prices:
    /// queued steals take the back of the lowest-priority nonempty queue
    /// (never reordering a class's FIFO), live steals take the
    /// lowest-class / least-sunk-wait suspended sequence — and the taken
    /// candidate matches the previewed one.
    #[test]
    fn steal_accessors_pick_lowest_class_tail_and_least_sunk_suspension() {
        let s = spec();
        let mut r = Replica::new(0, s.clone(), SchedulerMode::Continuous);
        assert!(r.steal_candidate_queued().is_none());
        assert!(r.take_steal_queued().is_none());
        r.enqueue(req_prio(0, 1, 4, Priority::High, &s, 1));
        r.enqueue(req_prio(1, 1, 4, Priority::Low, &s, 2));
        r.enqueue(req_prio(2, 1, 4, Priority::Low, &s, 3));
        assert_eq!(r.steal_candidate_queued().unwrap().id, 2, "Low-class tail first");
        assert_eq!(r.take_steal_queued().unwrap().id, 2);
        assert_eq!(r.take_steal_queued().unwrap().id, 1, "then the remaining Low");
        assert_eq!(r.take_steal_queued().unwrap().id, 0, "High only once Low drains");
        assert_eq!(r.queue_depth(), 0);

        // fabricate suspended state through the adoption path
        let mut donor = Replica::new(1, s.clone(), SchedulerMode::Continuous);
        donor.enqueue(req_prio(10, 1, 8, Priority::Normal, &s, 4));
        donor.enqueue(req_prio(11, 1, 8, Priority::Low, &s, 5));
        donor.run_one_step(2);
        let mut moved = donor.extract_live();
        assert_eq!(moved.len(), 2);
        moved[0].since = 1.0;
        moved[1].since = 2.0;
        let mut victim = Replica::new(2, s.clone(), SchedulerMode::Continuous);
        for m in moved {
            victim.adopt(m, 3.0);
        }
        let (req, step) = victim.steal_candidate_live().unwrap();
        assert_eq!(req.id, 11, "the Low-class suspension loses first");
        assert!(step > 0, "a stepped sequence carries its cursor");
        let m = victim.take_steal_suspended().unwrap();
        assert_eq!(m.req.id, 11);
        assert_eq!(m.since, 2.0, "the original suspension instant survives the take");
        assert_eq!(victim.suspended_len(), 1);
        assert_eq!(victim.take_steal_suspended().unwrap().req.id, 10);
        assert!(victim.take_steal_suspended().is_none());
    }

    /// Aging promotes a starved queued Low past τ (and to High past 2τ),
    /// counts each promotion, and leaves the run untouched when unarmed.
    #[test]
    fn age_promotion_lifts_starved_queued_low() {
        let s = spec();
        let run = |tau: Option<f64>| {
            let mut r =
                Replica::new(0, s.clone(), SchedulerMode::Continuous).with_age_promote(tau);
            // one long Normal hogs the single slot while a Low waits
            r.enqueue(req_prio(0, 1, 48, Priority::Normal, &s, 1));
            r.enqueue(req_prio(1, 1, 4, Priority::Low, &s, 2));
            r.run_until(f64::INFINITY, 1);
            r
        };
        let off = run(None);
        assert_eq!(off.promotions, 0, "unarmed aging never promotes");
        let low = off.completions.iter().find(|c| c.request_id == 1).unwrap();
        assert_eq!(low.priority, Priority::Low);
        let on = run(Some(1e-6));
        assert!(on.promotions >= 1, "a starved Low must age up");
        assert!(on.promotions <= 2, "one request promotes at most twice");
        let low = on.completions.iter().find(|c| c.request_id == 1).unwrap();
        assert_eq!(low.priority, Priority::High, "tiny τ ages straight to High");
        assert_eq!(on.completions.len(), 2, "promotion loses nothing");
    }
}
