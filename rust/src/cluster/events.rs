//! Sim-time event core for the cluster loop.
//!
//! `run_cluster` used to interleave its timeline selection with fault
//! handling and dispatch inside one loop body; this module factors the
//! timeline itself into an explicit [`EventQueue`] carrying every kind
//! of fleet-level event — request arrivals, retry wake-ups, the
//! deterministic fault plan (the PR 9 fault timeline merges into this
//! queue), and the periodic work-stealing scan.  The cluster loop pops
//! one event at a time, advances every replica to the event instant
//! (step boundaries and transfer landings replay *inside*
//! `Replica::run_until`, on each replica's own clock — they never need
//! fleet-level arbitration), and reacts.
//!
//! Ordering is the exact contract the polling loop implemented, kept
//! verbatim so existing seeds replay bit-identically:
//!
//! * Arrivals pop in FIFO order (the workload is pre-drawn sorted).
//! * Retry wake-ups pop at the minimum `ready_at`; the scan order over
//!   the pending set (first minimal element, `swap_remove` backfill)
//!   matches the historical `Vec` bookkeeping bit for bit.
//! * Fault events pop in plan order, and are suppressed once nothing is
//!   left to perturb (no arrivals, no retries, idle fleet).
//! * Ties resolve arrival ≤ retry ≤ fault ≤ steal.
//! * The steal tick is only visible while the run is live (work in
//!   flight, or arrivals/retries outstanding) — otherwise a drained
//!   fleet would tick forever — and disarmed entirely when
//!   `ClusterConfig::steal` is `None`, which reduces the queue to the
//!   exact pre-steal timeline.

use std::collections::VecDeque;

use crate::fault::FaultEvent;

use super::workload::ClusterRequest;

/// One fault-reclaimed (or fleet-down deferred) request waiting to
/// re-dispatch at `ready_at` under the retry policy's backoff.
pub(crate) struct RetryEntry {
    pub ready_at: f64,
    /// 0 for a deferred fresh arrival (no attempt burned), ≥ 1 for a
    /// genuine retry of a reclaimed request.
    pub attempt: u32,
    pub req: ClusterRequest,
}

/// One popped fleet-level event, tagged with what to do about it.
pub(crate) enum Event {
    /// A fresh request arrival (attempt 0).
    Arrival(ClusterRequest),
    /// A retry wake-up or fleet-down deferral re-entering dispatch.
    Retry(RetryEntry),
    /// The next entry of the deterministic fault plan.
    Fault(FaultEvent),
    /// Periodic work-stealing scan (armed by `ClusterConfig::steal`).
    StealTick,
}

/// The fleet's sim-time event queue (see module docs for the ordering
/// contract).
pub(crate) struct EventQueue {
    arrivals: VecDeque<ClusterRequest>,
    retries: Vec<RetryEntry>,
    faults: VecDeque<FaultEvent>,
    next_steal: f64,
    steal_interval: f64,
}

impl EventQueue {
    /// Build the queue over the pre-drawn arrivals and fault plan; a
    /// `None` steal interval disarms the tick entirely.
    pub fn new(
        arrivals: Vec<ClusterRequest>,
        faults: Vec<FaultEvent>,
        steal_interval: Option<f64>,
    ) -> EventQueue {
        let interval = steal_interval.unwrap_or(f64::INFINITY);
        EventQueue {
            arrivals: arrivals.into(),
            retries: Vec::new(),
            faults: faults.into(),
            next_steal: interval,
            steal_interval: interval,
        }
    }

    /// Whether the fault plan was non-empty at construction *or* any
    /// event remains — callers snapshot this before the first pop.
    pub fn faults_armed(&self) -> bool {
        !self.faults.is_empty()
    }

    /// Schedule a retry wake-up (or fleet-down deferral).
    pub fn push_retry(&mut self, entry: RetryEntry) {
        self.retries.push(entry);
    }

    /// Pop the earliest visible event, or `None` when the timeline is
    /// exhausted (trailing faults and steal ticks are moot once nothing
    /// is left to perturb).  `fleet_busy` is the caller's liveness
    /// snapshot, taken *before* advancing replicas — the same order the
    /// polling loop evaluated it in.
    pub fn pop(&mut self, fleet_busy: bool) -> Option<(f64, Event)> {
        let t_arr = self.arrivals.front().map(|r| r.at);
        let t_retry = self
            .retries
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.ready_at.total_cmp(&b.1.ready_at))
            .map(|(i, e)| (i, e.ready_at));
        // trailing fault events are moot once nothing is left to perturb
        let live = fleet_busy || t_arr.is_some() || t_retry.is_some();
        let t_fault = if live { self.faults.front().map(|e| e.at) } else { None };
        // earliest event wins; ties resolve arrival ≤ retry ≤ fault ≤ steal
        let ta = t_arr.unwrap_or(f64::INFINITY);
        let tr = t_retry.map_or(f64::INFINITY, |(_, t)| t);
        let tf = t_fault.unwrap_or(f64::INFINITY);
        let ts = if live { self.next_steal } else { f64::INFINITY };
        let now = ta.min(tr).min(tf).min(ts);
        if !now.is_finite() {
            return None;
        }
        let ev = if ta <= tr && ta <= tf && ta <= ts {
            Event::Arrival(self.arrivals.pop_front().expect("arrival front exists"))
        } else if tr <= tf && tr <= ts {
            let (i, _) = t_retry.expect("retry minimum exists");
            Event::Retry(self.retries.swap_remove(i))
        } else if tf <= ts {
            Event::Fault(self.faults.pop_front().expect("fault front exists"))
        } else {
            self.next_steal = now + self.steal_interval;
            Event::StealTick
        };
        Some((now, ev))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::FaultKind;

    fn req_at(id: u64, at: f64) -> ClusterRequest {
        let mut r = ClusterRequest::probe(0);
        r.id = id;
        r.at = at;
        r
    }

    #[test]
    fn arrivals_pop_in_order_and_queue_drains() {
        let mut q =
            EventQueue::new(vec![req_at(0, 0.0), req_at(1, 1.0), req_at(2, 2.0)], vec![], None);
        for want in 0..3u64 {
            match q.pop(false) {
                Some((t, Event::Arrival(r))) => {
                    assert_eq!(r.id, want);
                    assert_eq!(t, want as f64);
                }
                _ => panic!("expected arrival {want}"),
            }
        }
        assert!(q.pop(false).is_none());
    }

    #[test]
    fn tie_break_is_arrival_then_retry_then_fault_then_steal() {
        let fault = FaultEvent { at: 1.0, replica: 0, kind: FaultKind::Corrupt };
        let mut q = EventQueue::new(vec![req_at(0, 1.0)], vec![fault], Some(1.0));
        q.push_retry(RetryEntry { ready_at: 1.0, attempt: 1, req: req_at(9, 0.0) });
        assert!(matches!(q.pop(false), Some((_, Event::Arrival(_)))));
        assert!(matches!(q.pop(false), Some((_, Event::Retry(_)))));
        assert!(matches!(q.pop(false), Some((_, Event::Fault(_)))));
        // the fleet is idle and nothing is pending: the steal tick (and
        // the timeline) vanish rather than ticking forever
        assert!(q.pop(false).is_none());
        // a busy fleet keeps the tick alive, one interval at a time
        match q.pop(true) {
            Some((t, Event::StealTick)) => assert_eq!(t, 1.0),
            _ => panic!("expected steal tick"),
        }
        match q.pop(true) {
            Some((t, Event::StealTick)) => assert_eq!(t, 2.0),
            _ => panic!("expected rescheduled steal tick"),
        }
    }

    #[test]
    fn trailing_faults_are_moot_on_an_idle_fleet() {
        let fault = FaultEvent { at: 5.0, replica: 0, kind: FaultKind::Crash };
        let mut q = EventQueue::new(vec![], vec![fault], None);
        assert!(q.faults_armed());
        assert!(q.pop(false).is_none(), "nothing left to perturb");
        assert!(q.pop(true).is_some(), "a busy fleet still takes the fault");
    }

    #[test]
    fn retry_scan_matches_historical_swap_remove_order() {
        // two retries tie on ready_at: the first minimal element pops
        // first, exactly like the polling loop's min_by + swap_remove
        let mut q = EventQueue::new(vec![], vec![], None);
        q.push_retry(RetryEntry { ready_at: 2.0, attempt: 1, req: req_at(0, 0.0) });
        q.push_retry(RetryEntry { ready_at: 2.0, attempt: 1, req: req_at(1, 0.0) });
        q.push_retry(RetryEntry { ready_at: 1.0, attempt: 1, req: req_at(2, 0.0) });
        let ids: Vec<u64> = (0..3)
            .map(|_| match q.pop(false) {
                Some((_, Event::Retry(e))) => e.req.id,
                _ => panic!("expected retry"),
            })
            .collect();
        assert_eq!(ids, vec![2, 0, 1]);
    }
}
