//! Cluster experiment configuration: [`ClusterConfig`], the
//! work-stealing knobs ([`StealPolicy`]), and the validating
//! [`ClusterBuilder`].
//!
//! Nine PRs of accreted knobs (prefill chunking, lookahead, preemption,
//! quantization tiers, streaming mixes, fault plans, retry budgets, and
//! now work stealing and aging) used to be assembled by flat struct
//! literals scattered across `main.rs` and every repro experiment, each
//! re-implementing its own ad-hoc sanity checks.  The builder is the
//! one construction path: start from [`ClusterConfig::builder`]
//! (the synthetic baseline), override what the experiment varies, and
//! [`ClusterBuilder::build`] validates the *combination* — an invalid
//! config (a retry budget with no fault plan to retry from, preemption
//! under the static scheduler, a non-positive steal interval) fails at
//! build time with one error message listing every violation.
//!
//! The chainable `with_*` methods remain on [`ClusterConfig`] for
//! post-build arm sweeps (`base.clone().with_lookahead(d)`); they keep
//! their historical clamping semantics and perform no cross-knob
//! validation — that happens once, at build.

use anyhow::{bail, Result};

use crate::clock::GpuSpec;
use crate::coordinator::workload::Arrival;
use crate::coordinator::{PreemptPolicy, SchedulerMode};
use crate::fault::{FaultSpec, RetryPolicy};
use crate::quant::QuantMode;

use super::replica::ReplicaSpec;
use super::workload::{
    self, ClusterRequest, OutputLen, PriorityMix, StreamMix, TaskProfile, WorkloadSpec,
};

/// Fleet-scale work stealing knobs (`--steal`): every `interval`
/// sim-seconds, each idle dispatchable replica scans its peers and
/// takes the single best-priced piece of work — the back of a loaded
/// peer's lowest-priority queue, or (with `live` on) its
/// lowest-priority suspended in-flight sequence, charging the KV/plan
/// migration transfer over PCIe on the thief's clock.
///
/// Pricing mirrors the brownout-migration score: a steal fires only
/// when `(thief overlap − load_coeff · thief load) − (victim overlap −
/// load_coeff · (victim load − 1))`, minus the live steal's KV
/// transfer time normalized by the request's service estimate, exceeds
/// `threshold` — warm-cache advantage weighed against queue delay and
/// migration cost.
#[derive(Debug, Clone)]
pub struct StealPolicy {
    /// Sim-seconds between fleet-wide steal scans.
    pub interval: f64,
    /// Score subtracted per unit of outstanding load (queued plus
    /// in-flight), same scale as `ExpertAffinity::load_penalty`.
    pub load_coeff: f64,
    /// Minimum pricing gain before a steal fires (0.0 = any gain).
    pub threshold: f64,
    /// Also steal suspended in-flight sequences (live migration priced
    /// with the KV transfer charge); off limits stealing to queued work.
    pub live: bool,
}

impl StealPolicy {
    /// The default pricing at a given scan interval.
    pub fn every(interval: f64) -> StealPolicy {
        StealPolicy { interval, load_coeff: 0.1, threshold: 0.0, live: true }
    }
}

/// Full description of one cluster experiment.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub replicas: usize,
    /// Decode slots per replica.
    pub max_batch: usize,
    /// Admission bound: no replica's queue may exceed this depth.  When
    /// the balancer's choice is full the dispatcher sheds to the replica
    /// with the fewest queued requests; when *every* replica is full, the
    /// fleet advances step by step until a slot drains (lossless
    /// back-pressure).
    pub max_queue: usize,
    /// How replicas fill decode slots: step-level continuous batching or
    /// legacy run-to-completion batches.
    pub scheduler: SchedulerMode,
    /// Prompt tokens a prefilling sequence consumes per step on every
    /// replica (`--prefill-chunk`; 1 = token-at-a-time prefill).
    pub prefill_chunk: usize,
    /// When a waiting higher-priority request may preempt an in-flight
    /// sequence on a replica (`--preempt`; continuous scheduler only).
    pub preempt: PreemptPolicy,
    /// SLO-aware admission control on every replica (`--admission`):
    /// deadline-tagged requests whose compute-optimistic TTFT estimate
    /// already misses are rejected at admission instead of decoding only
    /// to miss at p99.
    pub admission: bool,
    /// Record sim-time structured traces on every replica plus the
    /// dispatcher lane (`--trace`); `run_cluster` then runs the
    /// cross-layer conservation audits per replica and returns the
    /// merged fleet timeline in [`super::ClusterReport::trace`].
    pub trace: bool,
    /// Deterministic fault plan parameters (`--faults`, `--mtbf`): drawn
    /// from a dedicated salt of the workload seed so fault-free runs are
    /// byte-identical whether or not this field is armed.
    pub faults: FaultSpec,
    /// Retry policy for fault-reclaimed requests (`--retry`): per-request
    /// budget with exponential sim-time backoff; an exhausted budget is
    /// the one terminal [`crate::coordinator::Outcome::Failed`].
    pub retry: RetryPolicy,
    /// Fleet-scale work stealing (`--steal`); `None` disarms the steal
    /// tick entirely, keeping the event timeline bit-identical to the
    /// pre-steal loop.
    pub steal: Option<StealPolicy>,
    /// Age-based priority promotion threshold τ in sim-seconds
    /// (`--age-promote`): a request waiting ≥ τ is promoted to Normal,
    /// ≥ 2τ to High, so a Low request under a sustained High flood has
    /// bounded `preempted_wait`.  `None` disables (zero behavior change).
    pub age_promote: Option<f64>,
    pub spec: ReplicaSpec,
    pub workload: WorkloadSpec,
    pub tasks: Vec<TaskProfile>,
}

impl ClusterConfig {
    /// Heterogeneous synthetic scenario: `n_tasks` fine-tuned traffic
    /// streams with tiled hot expert sets over OLMoE at paper scale, and
    /// a Poisson arrival rate ~1.5× the fleet's compute-only capacity so
    /// the comparison runs saturated (throughput reflects efficiency,
    /// not offered load).
    pub fn synthetic(
        replicas: usize,
        n_requests: usize,
        n_tasks: usize,
        gpu: GpuSpec,
        seed: u64,
    ) -> ClusterConfig {
        let spec = ReplicaSpec::olmoe(gpu);
        let tasks = TaskProfile::synthetic(
            n_tasks.max(1),
            spec.n_layers,
            spec.n_experts,
            spec.capacity,
            0.92,
        );
        let (prompt_tokens, max_output) = (8, 24);
        let est = spec.est_service_seconds(prompt_tokens, max_output).max(1e-6);
        let rate = 1.5 * replicas.max(1) as f64 / est;
        ClusterConfig {
            replicas: replicas.max(1),
            max_batch: 4,
            max_queue: n_requests.max(8),
            scheduler: SchedulerMode::Continuous,
            prefill_chunk: 1,
            preempt: PreemptPolicy::Off,
            admission: false,
            trace: false,
            faults: FaultSpec::none(),
            retry: RetryPolicy::off(),
            steal: None,
            age_promote: None,
            spec,
            workload: WorkloadSpec {
                n_requests,
                arrival: Arrival::Poisson(rate),
                prompt_tokens,
                output: OutputLen::Fixed(max_output),
                balanced_tasks: true,
                priorities: PriorityMix::none(),
                stream: StreamMix::none(),
                seed,
            },
            tasks,
        }
    }

    /// The validating construction path: the synthetic baseline wrapped
    /// in a [`ClusterBuilder`] (see the module docs).
    pub fn builder(
        replicas: usize,
        n_requests: usize,
        n_tasks: usize,
        gpu: GpuSpec,
        seed: u64,
    ) -> ClusterBuilder {
        ClusterBuilder { cfg: ClusterConfig::synthetic(replicas, n_requests, n_tasks, gpu, seed) }
    }

    pub fn with_arrival(mut self, arrival: Arrival) -> ClusterConfig {
        self.workload.arrival = arrival;
        self
    }

    /// Decode slots per replica (`--batch`).
    pub fn with_max_batch(mut self, slots: usize) -> ClusterConfig {
        self.max_batch = slots.max(1);
        self
    }

    pub fn with_max_queue(mut self, bound: usize) -> ClusterConfig {
        self.max_queue = bound.max(1);
        self
    }

    pub fn with_scheduler(mut self, scheduler: SchedulerMode) -> ClusterConfig {
        self.scheduler = scheduler;
        self
    }

    pub fn with_prefill_chunk(mut self, chunk: usize) -> ClusterConfig {
        self.prefill_chunk = chunk.max(1);
        self
    }

    /// Preemption policy applied on every replica (`--preempt`).
    pub fn with_preempt(mut self, preempt: PreemptPolicy) -> ClusterConfig {
        self.preempt = preempt;
        self
    }

    /// Record structured traces fleet-wide (`--trace`; see `trace`).
    pub fn with_trace(mut self, on: bool) -> ClusterConfig {
        self.trace = on;
        self
    }

    /// Per-request priority distribution of the generated workload.
    pub fn with_priority_mix(mut self, mix: PriorityMix) -> ClusterConfig {
        self.workload.priorities = mix;
        self
    }

    /// Per-request streaming-client behaviour of the generated workload:
    /// deadlines, cancel-after-N hang-ups and queue-time disconnects
    /// (`--deadline-mix` / `--cancel-after` / `--disconnect-rate`).
    pub fn with_stream_mix(mut self, mix: StreamMix) -> ClusterConfig {
        self.workload.stream = mix;
        self
    }

    /// SLO-aware admission control on every replica (`--admission`).
    pub fn with_admission(mut self, on: bool) -> ClusterConfig {
        self.admission = on;
        self
    }

    /// Fault-injection plan parameters (`--faults`, `--mtbf`; see
    /// [`FaultSpec`]).  [`FaultSpec::none`] keeps the run byte-identical
    /// to a build without the fault machinery.
    pub fn with_faults(mut self, faults: FaultSpec) -> ClusterConfig {
        self.faults = faults;
        self
    }

    /// Retry policy for fault-reclaimed requests (`--retry`).
    pub fn with_retry(mut self, retry: RetryPolicy) -> ClusterConfig {
        self.retry = retry;
        self
    }

    /// Fleet-scale work stealing (`--steal`; see [`StealPolicy`]).
    pub fn with_steal(mut self, steal: Option<StealPolicy>) -> ClusterConfig {
        self.steal = steal;
        self
    }

    /// Age-based priority promotion threshold (`--age-promote`; `None`
    /// disables — see `age_promote`).
    pub fn with_age_promote(mut self, tau: Option<f64>) -> ClusterConfig {
        self.age_promote = tau;
        self
    }

    /// Layer-ahead transfer pipeline depth on every replica
    /// (`--lookahead`; 0 = admit-time prefetch only).
    pub fn with_lookahead(mut self, depth: usize) -> ClusterConfig {
        self.spec = self.spec.with_lookahead(depth);
        self
    }

    /// Weight precision tier every replica stores and executes resident
    /// experts at (`--quant`).  Preserves the spec's VRAM *byte* budget:
    /// the per-layer slot count is rescaled by the tier cost ratio, so a
    /// lower-bit tier holds proportionally more experts in the same
    /// bytes (and the current tier is a no-op — cost units are exact
    /// binary fractions).
    pub fn with_quant(mut self, quant: QuantMode) -> ClusterConfig {
        let budget = self.spec.capacity as f64 * self.spec.quant.cost_units();
        self.spec.capacity =
            ((budget / quant.cost_units()) as usize).clamp(1, self.spec.n_experts);
        self.spec.quant = quant;
        self
    }

    /// Big-little fallback on every replica (`--little-tier`,
    /// `--fallback-threshold`): keep `little`-tier copies of the hottest
    /// experts resident and, on a demand miss, execute the little copy
    /// at zero stall when the expected wait exceeds `threshold` seconds.
    pub fn with_fallback(mut self, little: Option<QuantMode>, threshold: f64) -> ClusterConfig {
        self.spec = self.spec.with_fallback(little, threshold);
        self
    }

    pub fn with_output(mut self, output: OutputLen) -> ClusterConfig {
        self.workload.output = output;
        self
    }

    pub(crate) fn requests(&self) -> Vec<ClusterRequest> {
        workload::generate(
            &self.workload,
            &self.tasks,
            self.spec.n_layers,
            self.spec.n_experts,
            self.spec.top_k,
        )
    }
}

/// Validating builder over [`ClusterConfig`] (see the module docs).
/// Setters assign raw values — no silent clamping — and
/// [`ClusterBuilder::build`] reports every violation in one error.
#[derive(Debug, Clone)]
pub struct ClusterBuilder {
    cfg: ClusterConfig,
}

impl ClusterBuilder {
    /// Read access to the draft config mid-chain — CLI parsing derives
    /// dependent defaults (service-time estimates, fault horizons) from
    /// the knobs set so far without building prematurely.
    pub fn draft(&self) -> &ClusterConfig {
        &self.cfg
    }

    pub fn replicas(mut self, n: usize) -> ClusterBuilder {
        self.cfg.replicas = n;
        self
    }

    /// Decode slots per replica (`--batch`).
    pub fn max_batch(mut self, slots: usize) -> ClusterBuilder {
        self.cfg.max_batch = slots;
        self
    }

    pub fn max_queue(mut self, bound: usize) -> ClusterBuilder {
        self.cfg.max_queue = bound;
        self
    }

    pub fn scheduler(mut self, scheduler: SchedulerMode) -> ClusterBuilder {
        self.cfg.scheduler = scheduler;
        self
    }

    pub fn prefill_chunk(mut self, chunk: usize) -> ClusterBuilder {
        self.cfg.prefill_chunk = chunk;
        self
    }

    pub fn preempt(mut self, preempt: PreemptPolicy) -> ClusterBuilder {
        self.cfg.preempt = preempt;
        self
    }

    pub fn admission(mut self, on: bool) -> ClusterBuilder {
        self.cfg.admission = on;
        self
    }

    pub fn trace(mut self, on: bool) -> ClusterBuilder {
        self.cfg.trace = on;
        self
    }

    pub fn faults(mut self, faults: FaultSpec) -> ClusterBuilder {
        self.cfg.faults = faults;
        self
    }

    pub fn retry(mut self, retry: RetryPolicy) -> ClusterBuilder {
        self.cfg.retry = retry;
        self
    }

    pub fn steal(mut self, steal: Option<StealPolicy>) -> ClusterBuilder {
        self.cfg.steal = steal;
        self
    }

    pub fn age_promote(mut self, tau: Option<f64>) -> ClusterBuilder {
        self.cfg.age_promote = tau;
        self
    }

    /// Replace the replica model/memory spec wholesale (experiments that
    /// sweep model dimensions or VRAM budgets).
    pub fn spec(mut self, spec: ReplicaSpec) -> ClusterBuilder {
        self.cfg.spec = spec;
        self
    }

    /// Replace the task profiles (the synthetic default tiles the spec's
    /// expert space; experiments re-tile after changing the spec).
    pub fn tasks(mut self, tasks: Vec<TaskProfile>) -> ClusterBuilder {
        self.cfg.tasks = tasks;
        self
    }

    /// Replace the workload spec wholesale (keeps experiments that craft
    /// the full arrival process to a single call).
    pub fn workload(mut self, workload: WorkloadSpec) -> ClusterBuilder {
        self.cfg.workload = workload;
        self
    }

    pub fn arrival(mut self, arrival: Arrival) -> ClusterBuilder {
        self.cfg.workload.arrival = arrival;
        self
    }

    pub fn prompt_tokens(mut self, tokens: usize) -> ClusterBuilder {
        self.cfg.workload.prompt_tokens = tokens;
        self
    }

    pub fn output(mut self, output: OutputLen) -> ClusterBuilder {
        self.cfg.workload.output = output;
        self
    }

    pub fn priority_mix(mut self, mix: PriorityMix) -> ClusterBuilder {
        self.cfg.workload.priorities = mix;
        self
    }

    pub fn stream_mix(mut self, mix: StreamMix) -> ClusterBuilder {
        self.cfg.workload.stream = mix;
        self
    }

    /// Reweight the task profiles by a Zipf law (task `i` draws traffic
    /// ∝ `1/(i+1)^alpha`) and switch the workload to weighted draws —
    /// the imbalanced traffic work stealing exists to flatten.
    pub fn zipf(mut self, alpha: f64) -> ClusterBuilder {
        workload::zipf_weights(&mut self.cfg.tasks, alpha);
        self.cfg.workload.balanced_tasks = false;
        self
    }

    /// Serving tier with VRAM byte-budget rescale (`--quant`; delegates
    /// to [`ClusterConfig::with_quant`]).
    pub fn quant(mut self, quant: QuantMode) -> ClusterBuilder {
        self.cfg = self.cfg.with_quant(quant);
        self
    }

    /// Big-little fallback (see [`ClusterConfig::with_fallback`]).
    pub fn fallback(mut self, little: Option<QuantMode>, threshold: f64) -> ClusterBuilder {
        self.cfg = self.cfg.with_fallback(little, threshold);
        self
    }

    /// Layer-ahead transfer pipeline depth (`--lookahead`).
    pub fn lookahead(mut self, depth: usize) -> ClusterBuilder {
        self.cfg.spec = self.cfg.spec.with_lookahead(depth);
        self
    }

    /// Validate the combination and produce the config.  Every violation
    /// is collected, so one failed build reports all of them at once.
    pub fn build(self) -> Result<ClusterConfig> {
        let c = &self.cfg;
        let mut errs: Vec<String> = Vec::new();
        if c.replicas == 0 {
            errs.push("replicas must be >= 1".into());
        }
        if c.max_batch == 0 {
            errs.push("max_batch (decode slots) must be >= 1".into());
        }
        if c.max_queue == 0 {
            errs.push("max_queue (admission bound) must be >= 1".into());
        }
        if c.prefill_chunk == 0 {
            errs.push("prefill_chunk must be >= 1 (1 = token-at-a-time)".into());
        }
        if c.workload.n_requests == 0 {
            errs.push("workload must carry at least one request".into());
        }
        if c.tasks.is_empty() {
            errs.push("at least one task profile is required".into());
        }
        match c.workload.arrival {
            Arrival::Poisson(rate) if !(rate > 0.0 && rate.is_finite()) => {
                errs.push(format!("Poisson arrival rate must be positive and finite, got {rate}"));
            }
            Arrival::Uniform(gap) if !(gap >= 0.0 && gap.is_finite()) => {
                errs.push(format!("uniform arrival gap must be non-negative, got {gap}"));
            }
            _ => {}
        }
        if c.retry.max_retries > 0 && !c.faults.enabled {
            errs.push(
                "retry budget armed with fault injection off (--retry needs --faults): \
                 there is nothing to retry from"
                    .into(),
            );
        }
        if c.faults.enabled {
            if !(c.faults.mtbf > 0.0 && c.faults.mtbf.is_finite()) {
                errs.push(format!("fault MTBF must be positive and finite, got {}", c.faults.mtbf));
            }
            if !(c.faults.horizon > 0.0 && c.faults.horizon.is_finite()) {
                errs.push(format!(
                    "fault horizon must be positive and finite, got {}",
                    c.faults.horizon
                ));
            }
            if c.faults.recovery < 0.0 {
                errs.push(format!(
                    "crash recovery time cannot be negative, got {}",
                    c.faults.recovery
                ));
            }
        }
        if let Some(thresh) = c.preempt.threshold() {
            if c.scheduler != SchedulerMode::Continuous {
                errs.push(
                    "preemption (--preempt) requires the continuous scheduler: the static \
                     run-to-completion batch has no mid-flight slot to suspend"
                        .into(),
                );
            }
            if !(thresh >= 0.0 && thresh.is_finite()) {
                errs.push(format!(
                    "preemption threshold must be non-negative and finite, got {thresh}"
                ));
            }
        }
        if let Some(s) = &c.steal {
            if !(s.interval > 0.0 && s.interval.is_finite()) {
                errs.push(format!(
                    "steal interval must be positive and finite, got {}",
                    s.interval
                ));
            }
            if s.load_coeff < 0.0 || s.load_coeff.is_nan() {
                errs.push(format!(
                    "steal load coefficient cannot be negative, got {}",
                    s.load_coeff
                ));
            }
        }
        if let Some(tau) = c.age_promote {
            if !(tau > 0.0 && tau.is_finite()) {
                errs.push(format!(
                    "age-promotion threshold must be positive and finite, got {tau}"
                ));
            }
        }
        if c.spec.fallback_threshold < 0.0 {
            errs.push(format!(
                "fallback threshold cannot be negative, got {}",
                c.spec.fallback_threshold
            ));
        }
        if !errs.is_empty() {
            bail!("invalid cluster config: {}", errs.join("; "));
        }
        Ok(self.cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn builder() -> ClusterBuilder {
        ClusterConfig::builder(2, 16, 2, GpuSpec::h100(), 7)
    }

    #[test]
    fn synthetic_baseline_builds_clean() {
        let cfg = builder().build().unwrap();
        assert_eq!(cfg.replicas, 2);
        assert!(cfg.steal.is_none());
        assert!(cfg.age_promote.is_none());
    }

    #[test]
    fn retry_without_faults_fails_at_build() {
        let err = builder().retry(RetryPolicy::retries(3, 0.5)).build().unwrap_err();
        assert!(err.to_string().contains("--retry needs --faults"), "{err}");
    }

    #[test]
    fn one_error_message_lists_every_violation() {
        let err = builder()
            .max_batch(0)
            .retry(RetryPolicy::retries(3, 0.5))
            .steal(Some(StealPolicy::every(0.0)))
            .age_promote(Some(-1.0))
            .build()
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.starts_with("invalid cluster config:"), "{msg}");
        for needle in ["max_batch", "--retry needs --faults", "steal interval", "age-promotion"] {
            assert!(msg.contains(needle), "missing {needle:?} in {msg}");
        }
    }

    #[test]
    fn preempt_under_static_scheduler_rejected() {
        let err = builder()
            .scheduler(SchedulerMode::Static)
            .preempt(PreemptPolicy::After(0.5))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("continuous scheduler"), "{err}");
        // the same policy under the continuous scheduler is fine
        builder().preempt(PreemptPolicy::After(0.5)).build().unwrap();
    }

    #[test]
    fn armed_faults_validate_their_plan_parameters() {
        let mut faults = FaultSpec::none();
        faults.enabled = true; // mtbf/horizon still zero
        let err = builder().faults(faults).build().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("MTBF") && msg.contains("horizon"), "{msg}");
    }

    #[test]
    fn zipf_reweights_and_unbalances() {
        let cfg = builder().zipf(1.2).build().unwrap();
        assert!(!cfg.workload.balanced_tasks);
        assert!(cfg.tasks[0].weight > cfg.tasks[1].weight);
        assert!((cfg.tasks[0].weight - 1.0).abs() < 1e-12);
    }

    #[test]
    fn builder_draft_exposes_derived_defaults() {
        let b = builder();
        let est = b.draft().spec.est_service_seconds(8, 24);
        assert!(est > 0.0);
    }
}
