//! Simulated VRAM budget ledger.
//!
//! The paper caps per-process GPU memory (3 GB for OLMoE, 16 GB for
//! Phi-3.5-MoE, 24 GB for Mixtral-8x7B; §4.1) and derives how many experts
//! per layer can stay resident (Table 10).  This module does the same
//! arithmetic for the simulated hierarchy: given a budget, reserve the
//! always-resident weights (attention, norms, router, embeddings, KV
//! cache), and divide what remains among per-layer expert slots.

use crate::clock::PaperDims;
use crate::quant::QuantMode;

#[derive(Debug, Clone)]
pub struct VramBudget {
    pub budget_bytes: f64,
    pub dims: PaperDims,
}

impl VramBudget {
    pub fn new(budget_bytes: f64, dims: PaperDims) -> VramBudget {
        VramBudget { budget_bytes, dims }
    }

    pub fn gb(budget_gb: f64, dims: PaperDims) -> VramBudget {
        VramBudget::new(budget_gb * 1e9, dims)
    }

    /// Fixed runtime footprint: CUDA context, allocator slack, activation
    /// workspace (~1 GB on the paper's stacks).
    pub const RUNTIME_RESERVE: f64 = 1.0e9;

    /// Bytes that must always be resident: non-expert weights (fp16:
    /// attention + router + norms per layer, embeddings + tied head), the
    /// KV cache at 2k context, and the fixed runtime footprint.
    pub fn reserved_bytes(&self) -> f64 {
        let d = self.dims.d_model as f64;
        let per_layer = self.dims.attn_bytes() + 2.0 * self.dims.n_experts as f64 * d + 2.0 * 2.0 * d;
        let embed = 2.0 * self.dims.vocab as f64 * d; // tied head
        let kv = 2.0 * 2.0 * d * 2048.0 * self.dims.n_layers as f64; // 2k ctx fp16
        per_layer * self.dims.n_layers as f64 + embed + kv + Self::RUNTIME_RESERVE
    }

    /// Expert slots per layer under `mode` residency (uniform per layer,
    /// as in the paper; layer-wise budgets are listed as future work §5).
    pub fn capacity_per_layer(&self, mode: QuantMode) -> usize {
        let free = self.budget_bytes - self.reserved_bytes();
        if free <= 0.0 {
            return 0;
        }
        let slots = free / self.dims.expert_bytes(mode) / self.dims.n_layers as f64;
        (slots.floor() as usize).min(self.dims.n_experts)
    }

    /// Bytes actually used with a given per-layer capacity.
    pub fn used_bytes(&self, capacity: usize, mode: QuantMode) -> f64 {
        self.reserved_bytes()
            + capacity as f64 * self.dims.n_layers as f64 * self.dims.expert_bytes(mode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn olmoe() -> PaperDims {
        PaperDims { n_layers: 16, n_experts: 64, top_k: 8, d_model: 2048, d_ff: 1024, vocab: 50304 }
    }

    fn mixtral() -> PaperDims {
        PaperDims { n_layers: 32, n_experts: 8, top_k: 2, d_model: 4096, d_ff: 14336, vocab: 32000 }
    }

    #[test]
    fn paper_budgets_give_paper_capacities_olmoe() {
        // §4.1 allocates 3 GB for OLMoE; Table 10 keeps 16 experts/layer
        // resident (in INT4, per §3.2).
        let v = VramBudget::gb(3.0, olmoe());
        let cap = v.capacity_per_layer(QuantMode::Int4);
        assert!((12..=24).contains(&cap), "capacity {cap}");
    }

    #[test]
    fn paper_budgets_give_paper_capacities_mixtral() {
        // 24 GB budget, 5 of 8 experts/layer resident (INT4).
        let v = VramBudget::gb(24.0, mixtral());
        let cap = v.capacity_per_layer(QuantMode::Int4);
        assert!((4..=7).contains(&cap), "capacity {cap}");
    }

    #[test]
    fn capacity_monotone_in_budget() {
        let mut last = 0;
        for gb in [1.0, 2.0, 4.0, 8.0, 16.0] {
            let cap = VramBudget::gb(gb, olmoe()).capacity_per_layer(QuantMode::Fp16);
            assert!(cap >= last);
            last = cap;
        }
    }

    #[test]
    fn quant_fits_more() {
        let v = VramBudget::gb(3.0, olmoe());
        assert!(v.capacity_per_layer(QuantMode::Int4) > v.capacity_per_layer(QuantMode::Fp16));
    }

    #[test]
    fn capacity_capped_at_n_experts() {
        let v = VramBudget::gb(4000.0, olmoe());
        assert_eq!(v.capacity_per_layer(QuantMode::Fp16), 64);
    }

    #[test]
    fn tiny_budget_zero_capacity() {
        let v = VramBudget::gb(0.1, mixtral());
        assert_eq!(v.capacity_per_layer(QuantMode::Fp16), 0);
    }

    #[test]
    fn used_within_budget() {
        let v = VramBudget::gb(3.0, olmoe());
        let cap = v.capacity_per_layer(QuantMode::Int4);
        assert!(v.used_bytes(cap, QuantMode::Int4) <= v.budget_bytes * 1.001);
    }
}
