//! Activation-predictor inference + prefetch-set formation (§3.2).
//!
//! Pre-decode, the engine embeds the prompt (mean-pooled token
//! embeddings — the offline stand-in for BGE, DESIGN.md §2.4), runs the
//! Ψ_MLP artifact through PJRT, and takes the per-layer Top-C experts as
//! the prefetch set: `c^(ℓ,1) = Top-C([Ŷ(q)]_ℓ)` (paper Eq. 7).

use anyhow::Result;

use crate::moe::{MoeConfig, PredictorWeights, RoutingProfile};
use crate::runtime::Runtime;
use crate::tensor::HostTensor;

/// Per-layer prefetch sets.
#[derive(Debug, Clone)]
pub struct PrefetchPlan {
    pub per_layer: Vec<Vec<usize>>,
}

impl PrefetchPlan {
    pub fn empty(n_layers: usize) -> PrefetchPlan {
        PrefetchPlan { per_layer: vec![Vec::new(); n_layers] }
    }

    /// Fair union of several plans under per-layer capacity caps: experts
    /// are taken round-robin across the plans (first expert of each, then
    /// the second of each, ...) until the layer's cap fills.  The
    /// continuous scheduler refreshes a session's prefetch target with
    /// this whenever a sequence is admitted mid-flight — listing the
    /// in-flight union before the newcomer keeps the warm working set on
    /// capacity ties while still granting the newcomer a fair share.
    pub fn union_capped(plans: &[&PrefetchPlan], caps: &[usize]) -> PrefetchPlan {
        let n_layers = caps.len();
        let mut per_layer = Vec::with_capacity(n_layers);
        for (l, &cap) in caps.iter().enumerate() {
            let mut set: Vec<usize> = Vec::with_capacity(cap);
            let mut rank = 0usize;
            loop {
                let mut any = false;
                for plan in plans {
                    let Some(&e) = plan.per_layer.get(l).and_then(|s| s.get(rank)) else {
                        continue;
                    };
                    any = true;
                    if set.len() < cap && !set.contains(&e) {
                        set.push(e);
                    }
                }
                if !any || set.len() >= cap {
                    break;
                }
                rank += 1;
            }
            per_layer.push(set);
        }
        PrefetchPlan { per_layer }
    }
}

/// Layer-ahead candidate experts for `next_layer`, consulted while layer
/// ℓ = `next_layer - d` is still computing (the lookahead prefetch
/// pipeline; "Towards MoE Deployment"-style next-layer overlap).  Ranked
/// by source quality, deduplicated, at most `cap` experts:
///
/// 1. the sequence's admit-time plan at `next_layer` — the Ψ_MLP
///    predictor's (or routing profile's) per-layer Top-C, the same
///    machinery `predict_plan`/`profile_plan` feed;
/// 2. the session's observed activation counts at `next_layer` (an
///    online profile — what this session's traffic actually routed);
/// 3. layer ℓ's own selections as an identity prior, the last resort
///    when neither source knows anything about `next_layer` yet.
pub fn predict_next_layer(
    plan: &PrefetchPlan,
    counts: &[Vec<u64>],
    cur_selected: &[usize],
    next_layer: usize,
    cap: usize,
) -> Vec<usize> {
    let mut out: Vec<usize> = Vec::with_capacity(cap);
    if let Some(set) = plan.per_layer.get(next_layer) {
        for &e in set {
            if out.len() >= cap {
                return out;
            }
            if !out.contains(&e) {
                out.push(e);
            }
        }
    }
    if let Some(row) = counts.get(next_layer) {
        let mut ranked: Vec<usize> = (0..row.len()).filter(|&e| row[e] > 0).collect();
        ranked.sort_by(|&a, &b| row[b].cmp(&row[a]).then(a.cmp(&b)));
        for e in ranked {
            if out.len() >= cap {
                return out;
            }
            if !out.contains(&e) {
                out.push(e);
            }
        }
    }
    for &e in cur_selected {
        if out.len() >= cap {
            return out;
        }
        if !out.contains(&e) {
            out.push(e);
        }
    }
    out
}

/// Mean-pooled token embedding of the prompt: Ψ_EMB(q).
pub fn prompt_embedding(embed: &HostTensor, prompt: &[usize]) -> Vec<f32> {
    let d = embed.dims[1];
    let mut out = vec![0.0f32; d];
    if prompt.is_empty() {
        return out;
    }
    for &t in prompt {
        let row = embed.row(t.min(embed.dims[0] - 1));
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
    let n = prompt.len() as f32;
    for o in &mut out {
        *o /= n;
    }
    out
}

/// Predictor-driven plan: Top-C of Ψ_MLP(Ψ_EMB(q)) per layer.
pub fn predict_plan(
    rt: &Runtime,
    weights: &PredictorWeights,
    cfg: &MoeConfig,
    embed: &HostTensor,
    prompt: &[usize],
    capacity: usize,
) -> Result<PrefetchPlan> {
    let emb = prompt_embedding(embed, prompt);
    let scores = rt.predictor(&emb, weights)?; // [L, E]
    let mut per_layer = Vec::with_capacity(cfg.n_layers);
    for l in 0..cfg.n_layers {
        let row = HostTensor::new(vec![cfg.n_experts], scores.row(l).to_vec())?;
        per_layer.push(row.topk(capacity.min(cfg.n_experts)));
    }
    Ok(PrefetchPlan { per_layer })
}

/// Batched plan: pool the predictor scores across the batch's prompts
/// before taking Top-C (paper §4.3, "Effect of Batch Size").
pub fn predict_plan_batch(
    rt: &Runtime,
    weights: &PredictorWeights,
    cfg: &MoeConfig,
    embed: &HostTensor,
    prompts: &[Vec<usize>],
    capacity: usize,
) -> Result<PrefetchPlan> {
    let mut pooled = vec![0.0f32; cfg.n_layers * cfg.n_experts];
    for p in prompts {
        let emb = prompt_embedding(embed, p);
        let scores = rt.predictor(&emb, weights)?;
        for (acc, &v) in pooled.iter_mut().zip(&scores.data) {
            *acc += v;
        }
    }
    let mut per_layer = Vec::with_capacity(cfg.n_layers);
    for l in 0..cfg.n_layers {
        let row = HostTensor::new(
            vec![cfg.n_experts],
            pooled[l * cfg.n_experts..(l + 1) * cfg.n_experts].to_vec(),
        )?;
        per_layer.push(row.topk(capacity.min(cfg.n_experts)));
    }
    Ok(PrefetchPlan { per_layer })
}

/// MoE-Infinity-style plan from the historical activation profile.
pub fn profile_plan(profile: &RoutingProfile, cfg: &MoeConfig, capacity: usize) -> PrefetchPlan {
    PrefetchPlan {
        per_layer: (0..cfg.n_layers)
            .map(|l| profile.topc(l, capacity.min(cfg.n_experts)))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_pool_embedding() {
        let embed =
            HostTensor::new(vec![3, 2], vec![1.0, 0.0, 0.0, 1.0, 2.0, 2.0]).unwrap();
        let e = prompt_embedding(&embed, &[0, 1]);
        assert_eq!(e, vec![0.5, 0.5]);
        let e = prompt_embedding(&embed, &[2]);
        assert_eq!(e, vec![2.0, 2.0]);
        // out-of-range token clamps rather than panics
        let e = prompt_embedding(&embed, &[99]);
        assert_eq!(e, vec![2.0, 2.0]);
        assert_eq!(prompt_embedding(&embed, &[]), vec![0.0, 0.0]);
    }

    #[test]
    fn empty_plan_shape() {
        let p = PrefetchPlan::empty(4);
        assert_eq!(p.per_layer.len(), 4);
        assert!(p.per_layer.iter().all(|v| v.is_empty()));
    }

    #[test]
    fn union_capped_interleaves_fairly() {
        let a = PrefetchPlan { per_layer: vec![vec![0, 1, 2, 3]] };
        let b = PrefetchPlan { per_layer: vec![vec![10, 11, 12, 13]] };
        let u = PrefetchPlan::union_capped(&[&a, &b], &[4]);
        assert_eq!(u.per_layer[0], vec![0, 10, 1, 11]);
        // identical plans collapse to the plan itself
        let same = PrefetchPlan::union_capped(&[&a, &a], &[4]);
        assert_eq!(same.per_layer[0], vec![0, 1, 2, 3]);
        // cap larger than the union keeps everything
        let all = PrefetchPlan::union_capped(&[&a, &b], &[16]);
        assert_eq!(all.per_layer[0].len(), 8);
    }

    #[test]
    fn predict_next_layer_ranks_plan_then_counts_then_identity() {
        let plan = PrefetchPlan { per_layer: vec![vec![], vec![5, 6]] };
        let counts = vec![vec![0; 8], vec![0, 9, 0, 2, 0, 7, 0, 0]];
        // plan first (5, 6), then counts ranked 1 (9 hits) > 3 (2 hits);
        // 5's count never duplicates it; identity prior fills the tail
        let c = predict_next_layer(&plan, &counts, &[0, 7], 1, 8);
        assert_eq!(c, vec![5, 6, 1, 3, 0, 7]);
        // cap truncates in rank order
        assert_eq!(predict_next_layer(&plan, &counts, &[0, 7], 1, 3), vec![5, 6, 1]);
        // nothing known beyond the current selections: identity prior only
        let empty = PrefetchPlan::empty(2);
        let zero = vec![vec![0u64; 8]; 2];
        assert_eq!(predict_next_layer(&empty, &zero, &[2, 4], 1, 8), vec![2, 4]);
        // out-of-range layer: plan/counts rows missing are skipped
        assert_eq!(predict_next_layer(&empty, &zero, &[1], 7, 4), vec![1]);
        assert!(predict_next_layer(&empty, &zero, &[], 7, 4).is_empty());
    }

    #[test]
    fn union_capped_handles_ragged_layers() {
        let a = PrefetchPlan { per_layer: vec![vec![5], vec![7, 8]] };
        let b = PrefetchPlan::empty(1); // shorter plan: layer 1 missing
        let u = PrefetchPlan::union_capped(&[&a, &b], &[2, 2]);
        assert_eq!(u.per_layer, vec![vec![5], vec![7, 8]]);
        let none = PrefetchPlan::union_capped(&[], &[3, 3]);
        assert!(none.per_layer.iter().all(|s| s.is_empty()));
    }
}
