//! Serving metrics: throughput, latency distributions, transfer counters.

use crate::cache::CacheStats;
use crate::pcie::TransferStats;

/// Nearest-rank percentile of `values` (p in [0, 100]); 0.0 when empty.
/// Sorts a copy — callers on hot paths should batch their queries through
/// [`Percentiles::of`], which sorts once.  NaN-safe via `f64::total_cmp`:
/// positive NaNs order after every number (negative NaNs before), so
/// polluted samples surface at the extreme percentiles instead of
/// panicking mid-sort.
pub fn percentile(values: &[f64], p: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut v = values.to_vec();
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, p)
}

fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// The p50/p95/p99 triple every serving report wants (vLLM convention).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Percentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Percentiles {
    /// Compute all three with a single sort.
    pub fn of(values: &[f64]) -> Percentiles {
        if values.is_empty() {
            return Percentiles::default();
        }
        let mut v = values.to_vec();
        v.sort_by(f64::total_cmp);
        Percentiles {
            p50: percentile_sorted(&v, 50.0),
            p95: percentile_sorted(&v, 95.0),
            p99: percentile_sorted(&v, 99.0),
        }
    }

    /// "p50/p95/p99" cell for the table printers, scaled (e.g. 1e3 for ms).
    pub fn cell(&self, scale: f64) -> String {
        format!("{:.2}/{:.2}/{:.2}", self.p50 * scale, self.p95 * scale, self.p99 * scale)
    }
}

/// Outcome of decoding one request (or one batch-lockstep member).
#[derive(Debug, Clone)]
pub struct RequestMetrics {
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    /// Simulated seconds spent end-to-end (paper's time axis).
    pub sim_seconds: f64,
    /// Simulated seconds before the first output token.
    pub sim_ttft: f64,
    /// Host wallclock seconds (real PJRT execution, sanity only).
    pub wall_seconds: f64,
}

impl RequestMetrics {
    /// Output tokens per simulated second — the paper's throughput metric
    /// (Table 10: "Output tokens/s").
    pub fn tokens_per_sec(&self) -> f64 {
        if self.sim_seconds <= 0.0 {
            return 0.0;
        }
        self.output_tokens as f64 / self.sim_seconds
    }
}

/// Aggregated report over a workload run.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub requests: Vec<RequestMetrics>,
    pub cache: CacheStats,
    pub transfers: TransferStats,
    pub misses_per_layer: f64,
    pub wall_seconds: f64,
    /// Quality proxy for the big-little fallback: the fraction of routed
    /// (token, expert) assignments served by a degraded low-bit little
    /// copy instead of the full-tier weights.  0.0 whenever the fallback
    /// is disabled; always in [0, 1].
    pub degraded_token_frac: f64,
}

impl Report {
    pub fn total_output_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.output_tokens).sum()
    }

    pub fn total_sim_seconds(&self) -> f64 {
        self.requests.iter().map(|r| r.sim_seconds).sum()
    }

    /// Aggregate decoding throughput (output tokens per simulated second).
    pub fn tokens_per_sec(&self) -> f64 {
        let t = self.total_sim_seconds();
        if t <= 0.0 {
            return 0.0;
        }
        self.total_output_tokens() as f64 / t
    }

    pub fn mean_ttft(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().map(|r| r.sim_ttft).sum::<f64>() / self.requests.len() as f64
    }

    /// Latency percentile over per-request simulated times.
    pub fn latency_pct(&self, p: f64) -> f64 {
        let v: Vec<f64> = self.requests.iter().map(|r| r.sim_seconds).collect();
        percentile(&v, p)
    }

    /// p50/p95/p99 of per-request simulated latency.
    pub fn latency_percentiles(&self) -> Percentiles {
        let v: Vec<f64> = self.requests.iter().map(|r| r.sim_seconds).collect();
        Percentiles::of(&v)
    }

    /// p50/p95/p99 of simulated time-to-first-token.
    pub fn ttft_percentiles(&self) -> Percentiles {
        let v: Vec<f64> = self.requests.iter().map(|r| r.sim_ttft).collect();
        Percentiles::of(&v)
    }
}

/// `degraded / total` guarded against empty runs: the canonical
/// `degraded_token_frac` computation shared by engine and replica.
pub fn degraded_frac(degraded: u64, total: u64) -> f64 {
    if total == 0 {
        return 0.0;
    }
    (degraded as f64 / total as f64).clamp(0.0, 1.0)
}

/// Simple fixed-width table printer for the repro harnesses.
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.header, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }
}

pub fn fmt2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn fmt4(x: f64) -> String {
    format!("{x:.4}")
}

/// Fraction of expert-transfer time hidden behind compute:
/// `overlapped / (overlapped + stalled)`.  1.0 means every transfer was
/// fully pipelined behind execution, 0.0 means every transfer stalled
/// the decode (or there was no transfer time at all).
pub fn overlap_fraction(overlapped: f64, stalled: f64) -> f64 {
    let total = overlapped + stalled;
    if !total.is_finite() || total <= 0.0 {
        return 0.0;
    }
    (overlapped / total).clamp(0.0, 1.0)
}

/// "N.NNx" improvement of `value` over `baseline` for latency-like
/// metrics (baseline / value — higher is better; "n/a" when degenerate).
pub fn fmt_speedup(baseline: f64, value: f64) -> String {
    if value <= 0.0 || !baseline.is_finite() || !value.is_finite() {
        return "n/a".into();
    }
    format!("{:.2}x", baseline / value)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(out: usize, sim: f64) -> RequestMetrics {
        RequestMetrics {
            prompt_tokens: 4,
            output_tokens: out,
            sim_seconds: sim,
            sim_ttft: sim / 10.0,
            wall_seconds: 0.01,
        }
    }

    #[test]
    fn throughput_aggregates() {
        let mut r = Report::default();
        r.requests.push(req(10, 1.0));
        r.requests.push(req(30, 1.0));
        assert!((r.tokens_per_sec() - 20.0).abs() < 1e-9);
        assert_eq!(r.total_output_tokens(), 40);
    }

    #[test]
    fn latency_percentiles() {
        let mut r = Report::default();
        for i in 1..=100 {
            r.requests.push(req(1, i as f64));
        }
        assert!((r.latency_pct(50.0) - 50.0).abs() <= 1.0);
        assert!((r.latency_pct(99.0) - 99.0).abs() <= 1.0);
    }

    #[test]
    fn zero_division_safe() {
        let r = Report::default();
        assert_eq!(r.tokens_per_sec(), 0.0);
        assert_eq!(r.latency_pct(50.0), 0.0);
        assert_eq!(req(5, 0.0).tokens_per_sec(), 0.0);
    }

    #[test]
    fn empty_report_yields_finite_zeroes() {
        // a run that admitted nothing must report clean zeroes, not
        // NaN from 0/0 — repro JSON embeds these verbatim and
        // scripts/check_repro.py rejects non-finite values
        let r = Report::default();
        assert_eq!(r.mean_ttft(), 0.0);
        assert_eq!(r.total_output_tokens(), 0);
        assert_eq!(r.total_sim_seconds(), 0.0);
        assert_eq!(r.ttft_percentiles(), Percentiles::default());
        assert_eq!(r.latency_percentiles(), Percentiles::default());
        for v in [r.tokens_per_sec(), r.mean_ttft(), r.latency_pct(99.0)] {
            assert!(v.is_finite());
        }
    }

    #[test]
    fn percentile_helpers_agree_with_latency_pct() {
        let mut r = Report::default();
        for i in 1..=200 {
            r.requests.push(req(1, i as f64));
        }
        let p = r.latency_percentiles();
        assert_eq!(p.p50, r.latency_pct(50.0));
        assert_eq!(p.p95, r.latency_pct(95.0));
        assert_eq!(p.p99, r.latency_pct(99.0));
        assert!(p.p50 <= p.p95 && p.p95 <= p.p99);
        let t = r.ttft_percentiles();
        assert!((t.p50 - p.p50 / 10.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles_empty_and_single() {
        assert_eq!(Percentiles::of(&[]), Percentiles::default());
        assert_eq!(percentile(&[], 50.0), 0.0);
        let p = Percentiles::of(&[3.5]);
        assert_eq!((p.p50, p.p95, p.p99), (3.5, 3.5, 3.5));
        assert_eq!(percentile(&[2.0, 1.0], 0.0), 1.0);
        assert_eq!(percentile(&[2.0, 1.0], 100.0), 2.0);
    }

    #[test]
    fn percentile_nan_does_not_panic() {
        // total_cmp sorts positive NaN after every number: the median of
        // a mostly-clean sample stays meaningful, and nothing panics
        let v = [3.0, f64::NAN, 1.0, 2.0, f64::NAN];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert!(percentile(&v, 100.0).is_nan());
        let p = Percentiles::of(&v);
        assert_eq!(p.p50, 3.0);
        assert!(p.p99.is_nan());
        // all-NaN input must not panic either
        assert!(percentile(&[f64::NAN], 50.0).is_nan());
    }

    #[test]
    fn percentiles_cell_format() {
        let p = Percentiles { p50: 0.001, p95: 0.002, p99: 0.003 };
        assert_eq!(p.cell(1e3), "1.00/2.00/3.00");
    }

    #[test]
    fn overlap_fraction_ratio_and_guards() {
        assert_eq!(overlap_fraction(0.0, 0.0), 0.0);
        assert_eq!(overlap_fraction(1.0, 0.0), 1.0);
        assert_eq!(overlap_fraction(0.0, 2.0), 0.0);
        assert!((overlap_fraction(3.0, 1.0) - 0.75).abs() < 1e-12);
        // degenerate inputs stay in [0, 1] (negative overlap can appear
        // transiently mid-settlement; reporting clamps)
        assert_eq!(overlap_fraction(-1.0, 2.0), 0.0);
        assert_eq!(overlap_fraction(f64::NAN, 1.0), 0.0);
        assert_eq!(overlap_fraction(f64::INFINITY, 1.0), 0.0);
    }

    #[test]
    fn degraded_frac_bounded_and_zero_safe() {
        assert_eq!(degraded_frac(0, 0), 0.0);
        assert_eq!(degraded_frac(5, 0), 0.0);
        assert_eq!(degraded_frac(0, 10), 0.0);
        assert!((degraded_frac(3, 12) - 0.25).abs() < 1e-12);
        assert_eq!(degraded_frac(12, 12), 1.0);
        assert_eq!(Report::default().degraded_token_frac, 0.0);
    }

    #[test]
    fn speedup_formats_and_guards() {
        assert_eq!(fmt_speedup(3.0, 1.5), "2.00x");
        assert_eq!(fmt_speedup(1.0, 1.0), "1.00x");
        assert_eq!(fmt_speedup(1.0, 0.0), "n/a");
        assert_eq!(fmt_speedup(f64::NAN, 1.0), "n/a");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["model", "tok/s"]);
        t.row(vec!["olmoe-micro".into(), "22.16".into()]);
        let s = t.render();
        assert!(s.contains("| model       | tok/s |"));
        assert!(s.lines().count() == 3);
    }
}
