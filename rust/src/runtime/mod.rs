//! PJRT runtime: load the AOT HLO artifacts and dispatch them.
//!
//! One [`Runtime`] per model preset.  The four executables correspond to
//! the artifact contract in DESIGN.md §1; HLO *text* is the interchange
//! format (see `/opt/xla-example/README.md` — serialized jax≥0.5 protos
//! are rejected by xla_extension 0.5.1).
//!
//! All entry points speak host types (`Vec<f32>`, [`HostTensor`]) plus
//! opaque KV-cache literals that round-trip between calls without leaving
//! the runtime layer.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::moe::{LayerWeights, MoeConfig, PredictorWeights};
use crate::tensor::HostTensor;

pub struct Runtime {
    pub client: xla::PjRtClient,
    layer_step: xla::PjRtLoadedExecutable,
    expert_group: xla::PjRtLoadedExecutable,
    lm_head: xla::PjRtLoadedExecutable,
    predictor: xla::PjRtLoadedExecutable,
    /// Dispatch counters (perf accounting).
    pub calls_layer_step: std::cell::Cell<u64>,
    pub calls_expert_group: std::cell::Cell<u64>,
    pub calls_lm_head: std::cell::Cell<u64>,
}

/// Output of one `layer_step` invocation.
pub struct LayerStepOut {
    /// Router distribution over experts (host, for top-K).
    pub probs: HostTensor,
    /// Residual stream after attention (host, for the residual add).
    pub h_res: Vec<f32>,
    /// Expert input (normed hidden), stays device-side.
    pub h2: xla::Literal,
    pub k_cache: xla::Literal,
    pub v_cache: xla::Literal,
}

fn load_exe(client: &xla::PjRtClient, dir: &Path, name: &str) -> Result<xla::PjRtLoadedExecutable> {
    let path = dir.join("hlo").join(format!("{name}.hlo.txt"));
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
    )
    .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e:?}"))
}

impl Runtime {
    pub fn load(preset_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            layer_step: load_exe(&client, preset_dir, "layer_step")?,
            expert_group: load_exe(&client, preset_dir, "expert_group")?,
            lm_head: load_exe(&client, preset_dir, "lm_head")?,
            predictor: load_exe(&client, preset_dir, "predictor")?,
            client,
            calls_layer_step: std::cell::Cell::new(0),
            calls_expert_group: std::cell::Cell::new(0),
            calls_lm_head: std::cell::Cell::new(0),
        })
    }

    /// Fresh zeroed KV caches ([H, T_max, hd] each) for one sequence.
    pub fn init_kv(&self, cfg: &MoeConfig) -> Result<(xla::Literal, xla::Literal)> {
        let n = cfg.n_heads * cfg.max_seq * cfg.head_dim;
        let dims = [cfg.n_heads as i64, cfg.max_seq as i64, cfg.head_dim as i64];
        let k = xla::Literal::vec1(&vec![0f32; n]).reshape(&dims)?;
        let v = xla::Literal::vec1(&vec![0f32; n]).reshape(&dims)?;
        Ok((k, v))
    }

    /// Run one layer's pre-expert step.
    pub fn layer_step(
        &self,
        x: &[f32],
        weights: &LayerWeights,
        k_cache: &xla::Literal,
        v_cache: &xla::Literal,
        pos: usize,
    ) -> Result<LayerStepOut> {
        self.calls_layer_step.set(self.calls_layer_step.get() + 1);
        let x_lit = xla::Literal::vec1(x);
        let pos_lit = xla::Literal::scalar(pos as i32);
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(11);
        args.push(&x_lit);
        for w in &weights.lits {
            args.push(w);
        }
        args.push(k_cache);
        args.push(v_cache);
        args.push(&pos_lit);
        let res = self.layer_step.execute::<&xla::Literal>(&args)?[0][0]
            .to_literal_sync()
            .context("layer_step")?;
        let outs = res.to_tuple()?;
        let mut it = outs.into_iter();
        let probs = HostTensor::from_literal(&it.next().ok_or_else(|| anyhow!("missing probs"))?)?;
        let h_res = it.next().ok_or_else(|| anyhow!("missing h_res"))?.to_vec::<f32>()?;
        let h2 = it.next().ok_or_else(|| anyhow!("missing h2"))?;
        let k_cache = it.next().ok_or_else(|| anyhow!("missing k_cache"))?;
        let v_cache = it.next().ok_or_else(|| anyhow!("missing v_cache"))?;
        Ok(LayerStepOut { probs, h_res, h2, k_cache, v_cache })
    }

    /// Execute the grouped expert FFN for the routed experts.
    /// `gates` are the raw routing probabilities of `selected` (paper Eq. 1).
    pub fn expert_group(
        &self,
        gates: &[f32],
        h2: &xla::Literal,
        wg: &xla::Literal,
        wu: &xla::Literal,
        wd: &xla::Literal,
    ) -> Result<Vec<f32>> {
        self.calls_expert_group.set(self.calls_expert_group.get() + 1);
        let gates_lit = xla::Literal::vec1(gates);
        let args: Vec<&xla::Literal> = vec![&gates_lit, h2, wg, wu, wd];
        let res = self.expert_group.execute::<&xla::Literal>(&args)?[0][0]
            .to_literal_sync()
            .context("expert_group")?;
        Ok(res.to_tuple1()?.to_vec::<f32>()?)
    }

    /// Buffer-path variant: the (large) stacked expert weights are already
    /// device-resident `PjRtBuffer`s — only gates and h2 move per call.
    /// This is the §Perf fast path exploiting MELINOE's routing locality:
    /// the same routed set recurs across steps, so its device buffers are
    /// built once and re-dispatched.
    pub fn expert_group_b(
        &self,
        gates: &[f32],
        h2: &xla::Literal,
        wg: &xla::PjRtBuffer,
        wu: &xla::PjRtBuffer,
        wd: &xla::PjRtBuffer,
    ) -> Result<Vec<f32>> {
        self.calls_expert_group.set(self.calls_expert_group.get() + 1);
        let gates_b = self.client.buffer_from_host_buffer(gates, &[gates.len()], None)?;
        let h2_b = self.client.buffer_from_host_literal(None, h2)?;
        let args: Vec<&xla::PjRtBuffer> = vec![&gates_b, &h2_b, wg, wu, wd];
        let res = self.expert_group.execute_b::<&xla::PjRtBuffer>(&args)?[0][0]
            .to_literal_sync()
            .context("expert_group_b")?;
        Ok(res.to_tuple1()?.to_vec::<f32>()?)
    }

    /// Upload a host tensor to a device buffer.
    pub fn to_device(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Final norm + LM head; returns logits.
    pub fn lm_head(
        &self,
        x: &[f32],
        lnf: &xla::Literal,
        embed: &xla::Literal,
    ) -> Result<HostTensor> {
        self.calls_lm_head.set(self.calls_lm_head.get() + 1);
        let x_lit = xla::Literal::vec1(x);
        let args: Vec<&xla::Literal> = vec![&x_lit, lnf, embed];
        let res =
            self.lm_head.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync().context("lm_head")?;
        HostTensor::from_literal(&res.to_tuple1()?)
    }

    /// Activation predictor: prompt embedding → [L, E] preference scores.
    pub fn predictor(&self, emb: &[f32], weights: &PredictorWeights) -> Result<HostTensor> {
        let emb_lit = xla::Literal::vec1(emb);
        let mut args: Vec<&xla::Literal> = vec![&emb_lit];
        for w in &weights.lits {
            args.push(w);
        }
        let res = self.predictor.execute::<&xla::Literal>(&args)?[0][0]
            .to_literal_sync()
            .context("predictor")?;
        HostTensor::from_literal(&res.to_tuple1()?)
    }

    pub fn total_calls(&self) -> u64 {
        self.calls_layer_step.get() + self.calls_expert_group.get() + self.calls_lm_head.get()
    }
}
