//! Output-quality metrics: ROUGE-L, exact-match accuracy, perplexity.
//!
//! Mirrors the paper's Table 2 protocol: ROUGE-L on the instruction
//! dataset, answer accuracy on the math dataset; Table 4 reports
//! perplexity of the fine-tuned model across generation lengths.

/// Longest common subsequence length (O(n·m) DP).
pub fn lcs_len(a: &[usize], b: &[usize]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    let mut prev = vec![0usize; b.len() + 1];
    let mut cur = vec![0usize; b.len() + 1];
    for &x in a {
        for (j, &y) in b.iter().enumerate() {
            cur[j + 1] = if x == y { prev[j] + 1 } else { cur[j].max(prev[j + 1]) };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// ROUGE-L F1 between a candidate and a reference token sequence.
pub fn rouge_l(candidate: &[usize], reference: &[usize]) -> f64 {
    let lcs = lcs_len(candidate, reference) as f64;
    if lcs == 0.0 {
        return 0.0;
    }
    let p = lcs / candidate.len() as f64;
    let r = lcs / reference.len() as f64;
    2.0 * p * r / (p + r)
}

/// Exact-match accuracy for gsm-syn: the generated answer digits (tokens
/// after the ANS marker, before EOS) must equal the reference answer.
pub const ANS_TOKEN: usize = 25;
pub const EOS_TOKEN: usize = 2;
pub const DIG0_TOKEN: usize = 10;

pub fn extract_answer(generated: &[usize]) -> Option<String> {
    let start = generated.iter().position(|&t| t == ANS_TOKEN)? + 1;
    let mut s = String::new();
    for &t in &generated[start..] {
        if t == EOS_TOKEN {
            break;
        }
        if (DIG0_TOKEN..DIG0_TOKEN + 10).contains(&t) {
            s.push(char::from(b'0' + (t - DIG0_TOKEN) as u8));
        } else {
            return None; // malformed answer span
        }
    }
    if s.is_empty() {
        None
    } else {
        Some(s)
    }
}

pub fn answer_correct(generated: &[usize], answer: &str) -> bool {
    extract_answer(generated).as_deref() == Some(answer)
}

/// Perplexity from per-token negative log-likelihoods.
pub fn perplexity(nlls: &[f64]) -> f64 {
    if nlls.is_empty() {
        return f64::NAN;
    }
    (nlls.iter().sum::<f64>() / nlls.len() as f64).exp()
}

/// NLL of `target` under softmax(logits).
pub fn token_nll(logits: &[f32], target: usize) -> f64 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse = max
        + logits.iter().map(|&v| ((v as f64) - max).exp()).sum::<f64>().ln();
    lse - logits[target] as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lcs_basic() {
        assert_eq!(lcs_len(&[1, 2, 3, 4], &[2, 4]), 2);
        assert_eq!(lcs_len(&[1, 2, 3], &[4, 5, 6]), 0);
        assert_eq!(lcs_len(&[1, 2, 3], &[1, 2, 3]), 3);
        assert_eq!(lcs_len(&[], &[1]), 0);
    }

    #[test]
    fn rouge_identical_is_one() {
        assert!((rouge_l(&[5, 6, 7], &[5, 6, 7]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rouge_disjoint_is_zero() {
        assert_eq!(rouge_l(&[1, 2], &[3, 4]), 0.0);
    }

    #[test]
    fn rouge_partial() {
        // candidate [1,2,9], reference [1,2,3]: LCS=2, P=2/3, R=2/3, F1=2/3
        assert!((rouge_l(&[1, 2, 9], &[1, 2, 3]) - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn rouge_symmetric_in_f1() {
        let a = [1, 2, 3, 4, 5];
        let b = [1, 3, 5];
        assert!((rouge_l(&a, &b) - rouge_l(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn answer_extraction() {
        // ANS 1 2 EOS → "12"  (tokens: digit d is 10+d)
        assert_eq!(extract_answer(&[25, 11, 12, 2]), Some("12".into()));
        assert_eq!(extract_answer(&[25, 11]), Some("1".into()));
        assert_eq!(extract_answer(&[11, 12, 2]), None); // no ANS marker
        assert_eq!(extract_answer(&[25, 2]), None); // empty answer
        assert_eq!(extract_answer(&[25, 99, 2]), None); // non-digit
        assert!(answer_correct(&[25, 13, 2], "3"));
        assert!(!answer_correct(&[25, 13, 2], "4"));
    }

    #[test]
    fn perplexity_uniform() {
        // NLL = ln(4) per token → ppl = 4
        let nll = (4f64).ln();
        assert!((perplexity(&[nll, nll]) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn token_nll_matches_manual() {
        let logits = [1.0f32, 2.0, 3.0];
        let z: f64 = logits.iter().map(|&v| (v as f64).exp()).sum();
        let want = z.ln() - 2.0;
        assert!((token_nll(&logits, 1) - want).abs() < 1e-9);
    }
}
