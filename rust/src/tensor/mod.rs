//! Host tensors and `.npz` weight loading.
//!
//! [`HostTensor`] is a simple row-major f32 tensor used on the host side of
//! the engine (embedding gathers, residual adds, argmax).  Weight files are
//! the `.npz` archives written by `python/compile/aot.py`; they are read
//! through the xla crate's npy reader directly into [`xla::Literal`]s and
//! mirrored here for host access.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Result};
use xla::FromRawBytes;

/// Row-major f32 host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", dims, n, data.len());
        }
        Ok(HostTensor { dims, data })
    }

    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        HostTensor { dims, data: vec![0.0; n] }
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 2);
        let w = self.dims[1];
        &self.data[i * w..(i + 1) * w]
    }

    /// Sub-tensor at leading index `i` (rank reduced by one).
    pub fn slice0(&self, i: usize) -> HostTensor {
        assert!(self.rank() >= 1 && i < self.dims[0]);
        let inner: usize = self.dims[1..].iter().product();
        HostTensor {
            dims: self.dims[1..].to_vec(),
            data: self.data[i * inner..(i + 1) * inner].to_vec(),
        }
    }

    pub fn argmax(&self) -> usize {
        let mut best = 0;
        for (i, &v) in self.data.iter().enumerate() {
            if v > self.data[best] {
                best = i;
            }
        }
        best
    }

    /// Indices of the k largest entries, in descending value order.
    pub fn topk(&self, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.data.len()).collect();
        idx.sort_by(|&a, &b| self.data[b].partial_cmp(&self.data[a]).unwrap());
        idx.truncate(k);
        idx
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.rank() == 1 {
            Ok(lit)
        } else {
            let dims: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        HostTensor::new(dims, data)
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// Element-wise a + b.
pub fn add(a: &[f32], b: &[f32]) -> Vec<f32> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x + y).collect()
}

/// A named collection of tensors loaded from one `.npz` file.
#[derive(Debug, Default)]
pub struct NpzFile {
    pub tensors: BTreeMap<String, HostTensor>,
}

impl NpzFile {
    pub fn load(path: impl AsRef<Path>) -> Result<NpzFile> {
        let entries = xla::Literal::read_npz(path.as_ref(), &())
            .map_err(|e| anyhow!("npz {:?}: {e:?}", path.as_ref()))?;
        let mut tensors = BTreeMap::new();
        for (name, lit) in entries {
            // weights may be f32 or f64 depending on numpy defaults; coerce.
            let lit = match lit.ty() {
                Ok(xla::ElementType::F32) => lit,
                _ => lit.convert(xla::PrimitiveType::F32)?,
            };
            tensors.insert(name, HostTensor::from_literal(&lit)?);
        }
        Ok(NpzFile { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.tensors.get(name).ok_or_else(|| anyhow!("npz missing tensor {name:?}"))
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.keys().map(|s| s.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn row_and_slice() {
        let t = HostTensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
        let s = t.slice0(0);
        assert_eq!(s.dims, vec![3]);
        assert_eq!(s.data, vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn argmax_topk() {
        let t = HostTensor::new(vec![5], vec![0.1, 0.9, 0.3, 0.95, 0.2]).unwrap();
        assert_eq!(t.argmax(), 3);
        assert_eq!(t.topk(2), vec![3, 1]);
        assert_eq!(t.topk(5), vec![3, 1, 2, 4, 0]);
    }

    #[test]
    fn literal_roundtrip() {
        let t = HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn add_elementwise() {
        assert_eq!(add(&[1.0, 2.0], &[3.0, -2.0]), vec![4.0, 0.0]);
    }
}
