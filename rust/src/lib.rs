//! MELINOE: memory-efficient MoE serving via routing-locality fine-tuning.
//!
//! Reproduction of *MELINOE: Fine-Tuning Enables Memory-Efficient Inference
//! for Mixture-of-Experts Models* (Raje, Nayak, Joshi; CMU 2026).
//!
//! This crate is the L3 request path of the three-layer stack (see
//! DESIGN.md): it loads the AOT-compiled HLO artifacts produced by the
//! python build layer (`python/compile/aot.py`) and runs *offloaded* MoE
//! inference under a simulated GPU memory hierarchy — expert caches, PCIe
//! transfer engine, VRAM budgets, activation-predictor prefetching — with
//! the paper's five baselines implemented as alternative offload policies.
//!
//! Module map:
//! * [`util`]        — from-scratch JSON / CLI / RNG / property-testing
//!                     (offline image carries no serde/clap/proptest).
//! * [`tensor`]      — host tensors + `.npz` weight loading.
//! * [`quant`]       — INT4/INT3 group quantization (HQQ stand-in), now
//!                     a first-class serving dimension: per-tier byte
//!                     costs (`QuantMode::cost_units`) drive the cache's
//!                     byte budgets, PCIe transfer durations and the
//!                     big-little fallback's degraded numerics
//!                     (`--quant` / `--little-tier`, Table 12,
//!                     `ext_quant`).
//! * [`clock`]       — simulated clock + GPU/PCIe cost models (paper
//!                     Eq. 3), incl. the chunked-prefill exec term
//!                     (`CostModel::chunk_exec_time`).
//! * [`vram`]        — VRAM budget ledger (capacity derivation, Fig. 11).
//! * [`pcie`]        — asynchronous H2D/D2H transfer pipeline: FIFO link
//!                     with tracked in-flight `(layer, expert)` entries,
//!                     residual waits on caught prefetches, the
//!                     stall/overlap accounting split (Fig. 1a,
//!                     `ext_overlap`), and byte-accurate per-tier
//!                     transfer costing with per-tier byte counters the
//!                     trace audits reconcile to 1e-6.
//! * [`cache`]       — per-layer expert caches: LRU / LFU / γ-discounted
//!                     (paper Def. C.1), the reserve/commit path for
//!                     in-flight prefetch residency, the
//!                     scheduler-owned pin ledger (`pin_set`/`release`)
//!                     protecting live sequences' planned hot sets from
//!                     bulk admissions and lookahead commits, and
//!                     byte-budgeted per-tier residency with an optional
//!                     little store of low-bit fallback copies
//!                     (`enable_little`).
//! * [`moe`]         — model config + weight store (base / fine-tuned).
//! * [`runtime`]     — PJRT executable loading & dispatch (xla crate).
//! * [`predictor`]   — activation-predictor inference + prefetch sets
//!                     (capped union plans for mid-flight refresh, and
//!                     `predict_next_layer` layer-ahead candidates for
//!                     the lookahead pipeline).
//! * [`engine`]      — the offloaded decode engine: step-granular
//!                     `DecodeSession`s (admit/step/retire-at-EOS,
//!                     suspend/resume with bit-identical continuation,
//!                     chunked prefill via `prefill_chunk`, layer-ahead
//!                     lookahead prefetch with residual waits, the
//!                     session-persistent device-buffer memo, and the
//!                     big-little fallback executing degraded low-bit
//!                     copies at zero stall under `--fallback-threshold`)
//!                     with `decode`/`decode_batch` as thin wrappers.
//! * [`policies`]    — MELINOE + Fiddler / Mixtral-Offloading /
//!                     DeepSpeed-MoE / FLoE / MoE-Infinity.
//! * [`coordinator`] — request queue + step-level scheduler: continuous
//!                     batching (admit every token step, retire at EOS)
//!                     or static run-to-completion batches; per-step
//!                     prefill token budget (`--prefill-chunk`);
//!                     priority classes with per-class queues and
//!                     `--preempt` suspend/resume preemption; the
//!                     streaming front-end (`RequestSpec` submission,
//!                     per-token `TokenStream` handles with bounded-
//!                     buffer backpressure, cancel/disconnect, SLO-aware
//!                     admission, terminal `Outcome`s); TTFT/TPOT +
//!                     preempted-wait + goodput serving stats (see
//!                     docs/SERVING.md).
//! * [`eval`]        — ROUGE-L, exact-match accuracy, perplexity.
//! * [`metrics`]     — throughput/latency/transfer reporting.
//! * [`trace`]       — sim-time structured event recorder (zero-alloc
//!                     when off), metrics registry with per-expert churn
//!                     and per-layer stall tables, Chrome trace-event /
//!                     Perfetto export, and the cross-layer conservation
//!                     audits reconciling the event stream against
//!                     `TransferStats` and the cache's pin ledger /
//!                     occupancy (see docs/OBSERVABILITY.md).
//! * [`repro`]       — one harness per paper table/figure.
//!
//! Cluster layer (the first tier above the single-engine stack):
//! * [`cluster`]     — replica fleet simulator around an event-driven
//!   core: one sim-time priority queue carries arrival, retry-wake,
//!   fault and steal-tick events, and replicas advance only when an
//!   event lands on them.  Per-replica cache/PCIe/VRAM/clock stacks
//!   with step-granular decode slots (per-priority queues, `--preempt`
//!   suspend/resume, `--age-promote` anti-starvation aging, per-class
//!   latency slices, streaming clients via `StreamMix` with SLO-aware
//!   admission and goodput accounting), behind pluggable health-aware
//!   dispatchers (round-robin, least-loaded, expert-affinity, and the
//!   opt-in priority-affinity) that see live `Replica::view()`
//!   snapshots.  Fleet-scale work stealing (`--steal`) lets idle
//!   replicas take queued or suspended work from loaded peers, priced
//!   by warm-cache affinity against queue delay and KV migration cost.
//!   Configs are assembled through the validating `ClusterBuilder`
//!   (see docs/CLUSTER.md).
//! * [`fault`]       — fleet fault injection and recovery: seedable
//!   `FaultPlan` (crashes, brownouts, PCIe link flaps, transfer
//!   corruption) drawn from a dedicated RNG stream and injected as
//!   events on the cluster's sim-time queue, the per-replica `Health`
//!   state machine with a phi-style heartbeat detector, and the
//!   `RetryPolicy` (`--retry`) under which every reclaimed request
//!   still resolves exactly one terminal `Outcome` — now including
//!   `Outcome::Failed` (see docs/ROBUSTNESS.md).

pub mod cache;
pub mod clock;
pub mod cluster;
pub mod coordinator;
pub mod engine;
pub mod eval;
pub mod fault;
pub mod metrics;
pub mod moe;
pub mod pcie;
pub mod policies;
pub mod predictor;
pub mod quant;
pub mod repro;
pub mod runtime;
pub mod tensor;
pub mod trace;
pub mod util;
pub mod vram;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$MELINOE_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("MELINOE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from(ARTIFACTS_DIR))
}
