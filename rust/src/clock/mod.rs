//! Simulated clock + analytic GPU/PCIe cost model (paper Eq. 3).
//!
//! Numerics run on CPU PJRT, so wallclock is meaningless for reproducing
//! the paper's *throughput* numbers.  Instead the engine advances a
//! [`SimClock`] using a roofline cost model evaluated at the **paper-scale**
//! dimensions (Table 6) on the paper's GPUs (Table 9):
//!
//! ```text
//! Time_decode ≈ Time_compute + N_miss · Time_transfer          (Eq. 3)
//! ```
//!
//! Compute is memory-bandwidth-bound at batch 1 (weights streamed from
//! HBM) plus a per-layer framework dispatch overhead calibrated against
//! Table 1's all-resident rows; transfers are `latency + bytes/bw` over
//! the PCIe link of the selected testbed.  Cache misses, transfer counts,
//! and routing behaviour are *measured* from the real micro-model — only
//! the time axis is modeled.

use crate::quant::QuantMode;

/// Paper-scale model dimensions (Table 6) used exclusively for costing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PaperDims {
    pub n_layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub d_model: usize,
    pub d_ff: usize,
    /// LM vocabulary at paper scale (OLMoE ≈ 50k; default used for all).
    pub vocab: usize,
}

impl PaperDims {
    /// fp16 bytes of one expert's (gate, up, down) projections.
    pub fn expert_bytes_fp16(&self) -> f64 {
        2.0 * 3.0 * self.d_model as f64 * self.d_ff as f64
    }

    /// Bytes of one expert under a residency quantization mode.
    pub fn expert_bytes(&self, mode: QuantMode) -> f64 {
        3.0 * self.d_model as f64 * self.d_ff as f64 * mode.bytes_per_element()
    }

    /// FLOPs to execute one expert for one token.
    pub fn expert_flops(&self) -> f64 {
        2.0 * 3.0 * self.d_model as f64 * self.d_ff as f64
    }

    /// fp16 bytes of a layer's attention weights (q,k,v,o).
    pub fn attn_bytes(&self) -> f64 {
        2.0 * 4.0 * (self.d_model as f64).powi(2)
    }
}

/// One of the paper's hardware testbeds (Table 9) + calibration constants.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Dense fp16 throughput, FLOP/s.
    pub flops: f64,
    /// PCIe bandwidth (Table 9), bytes/s.
    pub pcie_bw: f64,
    /// Per-transfer PCIe latency, s.
    pub pcie_lat: f64,
    /// Per-layer per-step framework dispatch overhead, s (calibrated so the
    /// all-resident rows of Table 1 land at the paper's tok/s).
    pub layer_overhead: f64,
    /// Host effective memory bandwidth for CPU expert execution (Fiddler).
    pub cpu_bw: f64,
    /// Host compute for CPU expert execution, FLOP/s.
    pub cpu_flops: f64,
    /// VRAM capacity in bytes (Table 9).
    pub vram_bytes: f64,
}

pub const GB: f64 = 1e9;

impl GpuSpec {
    pub fn h100() -> GpuSpec {
        GpuSpec {
            name: "h100",
            hbm_bw: 3350.0 * GB,
            flops: 700e12,
            pcie_bw: 64.0 * GB,
            pcie_lat: 12e-6,
            layer_overhead: 1.6e-3,
            cpu_bw: 60.0 * GB,
            cpu_flops: 1.5e12,
            vram_bytes: 80.0 * GB,
        }
    }

    pub fn a100() -> GpuSpec {
        GpuSpec {
            name: "a100",
            hbm_bw: 1555.0 * GB,
            flops: 312e12,
            pcie_bw: 32.0 * GB,
            pcie_lat: 15e-6,
            layer_overhead: 1.9e-3,
            cpu_bw: 55.0 * GB,
            cpu_flops: 1.2e12,
            vram_bytes: 40.0 * GB,
        }
    }

    pub fn rtx4090() -> GpuSpec {
        GpuSpec {
            name: "rtx4090",
            hbm_bw: 1008.0 * GB,
            flops: 165e12,
            pcie_bw: 32.0 * GB,
            pcie_lat: 15e-6,
            layer_overhead: 2.2e-3,
            cpu_bw: 50.0 * GB,
            cpu_flops: 1.0e12,
            vram_bytes: 24.0 * GB,
        }
    }

    pub fn by_name(name: &str) -> anyhow::Result<GpuSpec> {
        Ok(match name {
            "h100" => GpuSpec::h100(),
            "a100" => GpuSpec::a100(),
            "rtx4090" | "4090" => GpuSpec::rtx4090(),
            _ => anyhow::bail!("unknown gpu {name:?} (h100|a100|rtx4090)"),
        })
    }
}

/// Monotone simulated clock.
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    now: f64,
}

impl SimClock {
    pub fn new() -> SimClock {
        SimClock { now: 0.0 }
    }

    pub fn advance(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "negative time step {dt}");
        self.now += dt;
    }

    pub fn now(&self) -> f64 {
        self.now
    }
}

/// Roofline cost model: (GPU testbed) × (paper-scale dims).
#[derive(Debug, Clone)]
pub struct CostModel {
    pub gpu: GpuSpec,
    pub dims: PaperDims,
    /// Extra compute factor when executing dequantized residents
    /// (Table 12: quantization's benefit is sub-proportional).
    pub dequant_overhead: f64,
}

impl CostModel {
    pub fn new(gpu: GpuSpec, dims: PaperDims) -> CostModel {
        CostModel { gpu, dims, dequant_overhead: 1.3 }
    }

    /// One expert H2D (or D2H) transfer.
    pub fn transfer_time(&self, mode: QuantMode) -> f64 {
        self.gpu.pcie_lat + self.dims.expert_bytes(mode) / self.gpu.pcie_bw
    }

    /// Per-layer non-expert compute for a decode step over `batch` tokens.
    pub fn attn_time(&self, batch: usize) -> f64 {
        let bytes = self.dims.attn_bytes();
        let flops = 8.0 * (self.dims.d_model as f64).powi(2) * batch as f64;
        self.gpu.layer_overhead + (bytes / self.gpu.hbm_bw).max(flops / self.gpu.flops)
    }

    /// Expert execution on GPU: `unique` distinct experts stream their
    /// weights from HBM once, and `assignments` (token, expert) pairs run
    /// on the MXU/tensor cores.
    pub fn expert_exec_time(&self, unique: usize, assignments: usize, mode: QuantMode) -> f64 {
        let overhead = if mode == QuantMode::Fp16 { 1.0 } else { self.dequant_overhead };
        let mem = unique as f64 * self.dims.expert_bytes(mode) / self.gpu.hbm_bw;
        let compute = assignments as f64 * self.dims.expert_flops() / self.gpu.flops;
        (mem + compute) * overhead
    }

    /// Expert execution for one sequence's share of a step that consumes
    /// `step_tokens` tokens in total across the live batch (decode tokens
    /// plus piggybacked prefill-chunk tokens — the Sarathi decomposition).
    /// The `unique` distinct experts of the chunk's union set stream
    /// their weights once, amortized over every token the step consumes,
    /// while each of the `assignments` (token, expert) pairs pays its own
    /// MXU compute.  At `step_tokens == 1` this is exactly
    /// [`CostModel::expert_exec_time`] — a lone single-token step.
    pub fn chunk_exec_time(
        &self,
        unique: usize,
        assignments: usize,
        step_tokens: usize,
        mode: QuantMode,
    ) -> f64 {
        if step_tokens <= 1 {
            return self.expert_exec_time(unique, assignments, mode);
        }
        self.expert_exec_time(unique, assignments, mode) / step_tokens as f64
            + self.dims.expert_flops() * assignments as f64 / self.gpu.flops
    }

    /// Fiddler-style CPU execution of one expert over `assignments` tokens
    /// (weights stay in DRAM; activations move instead of weights).
    pub fn cpu_expert_time(&self, assignments: usize) -> f64 {
        let mem = self.dims.expert_bytes_fp16() / self.gpu.cpu_bw;
        let compute = assignments as f64 * self.dims.expert_flops() / self.gpu.cpu_flops;
        // activation round-trip over PCIe (tiny: 2 · d_model · batch)
        let act = 2.0 * 2.0 * self.dims.d_model as f64 * assignments as f64 / self.gpu.pcie_bw;
        mem + compute + act + 2.0 * self.gpu.pcie_lat
    }

    /// Per-token fixed tail: final norm + LM head read.
    pub fn head_time(&self, batch: usize) -> f64 {
        let bytes = 2.0 * self.dims.vocab as f64 * self.dims.d_model as f64;
        bytes / self.gpu.hbm_bw * (1.0 + 0.02 * (batch as f64 - 1.0))
    }

    /// Activation-predictor MLP forward (µs-scale; paper: ~0.05 s per
    /// request including prefetch issue).
    pub fn predictor_time(&self) -> f64 {
        1e-3
    }

    /// All-resident decode time per token (used in tests / sanity checks).
    pub fn ideal_token_time(&self) -> f64 {
        let l = self.dims.n_layers;
        l as f64 * (self.attn_time(1) + self.expert_exec_time(self.dims.top_k, self.dims.top_k, QuantMode::Fp16))
            + self.head_time(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn olmoe_dims() -> PaperDims {
        PaperDims { n_layers: 16, n_experts: 64, top_k: 8, d_model: 2048, d_ff: 1024, vocab: 50304 }
    }

    fn mixtral_dims() -> PaperDims {
        PaperDims { n_layers: 32, n_experts: 8, top_k: 2, d_model: 4096, d_ff: 14336, vocab: 32000 }
    }

    #[test]
    fn clock_monotone() {
        let mut c = SimClock::new();
        c.advance(0.5);
        c.advance(0.25);
        assert!((c.now() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn mixtral_transfer_matches_paper_quote() {
        // §4.3: "Even with PCIe 5 x16, a single expert transfer for
        // Mixtral-8x7B without quantization can take 5-6 ms."
        let cm = CostModel::new(GpuSpec::h100(), mixtral_dims());
        let t = cm.transfer_time(QuantMode::Fp16);
        assert!((0.004..0.008).contains(&t), "transfer {t}s");
    }

    #[test]
    fn olmoe_all_resident_near_table1() {
        // Table 1: OLMoE with all experts resident on H100 = 37.84 tok/s.
        let cm = CostModel::new(GpuSpec::h100(), olmoe_dims());
        let tok_s = 1.0 / cm.ideal_token_time();
        assert!((25.0..55.0).contains(&tok_s), "got {tok_s} tok/s");
    }

    #[test]
    fn quantized_transfer_cheaper() {
        let cm = CostModel::new(GpuSpec::a100(), mixtral_dims());
        assert!(cm.transfer_time(QuantMode::Int4) < cm.transfer_time(QuantMode::Fp16) / 3.0);
        assert!(cm.transfer_time(QuantMode::Int3) < cm.transfer_time(QuantMode::Int4));
    }

    #[test]
    fn cpu_vs_transfer_tradeoff_shape() {
        // Fiddler's premise: for few tokens, CPU execution beats weight
        // transfer on big experts; for many tokens it loses (§1).
        let cm = CostModel::new(GpuSpec::rtx4090(), mixtral_dims());
        let transfer_then_gpu =
            cm.transfer_time(QuantMode::Fp16) + cm.expert_exec_time(1, 1, QuantMode::Fp16);
        assert!(cm.cpu_expert_time(1) < transfer_then_gpu * 1.2);
        assert!(cm.cpu_expert_time(512) > cm.transfer_time(QuantMode::Fp16));
    }

    #[test]
    fn chunk_exec_reduces_to_expert_exec_when_alone() {
        let cm = CostModel::new(GpuSpec::h100(), olmoe_dims());
        let a = cm.chunk_exec_time(8, 8, 1, QuantMode::Fp16);
        let b = cm.expert_exec_time(8, 8, QuantMode::Fp16);
        assert!((a - b).abs() < 1e-15);
    }

    #[test]
    fn chunk_exec_amortizes_streaming_over_step_tokens() {
        // a chunk of 8 prompt tokens routing to the same 8 experts must
        // cost less than 8 single-token steps: the weights stream once
        let cm = CostModel::new(GpuSpec::h100(), olmoe_dims());
        let chunked = cm.chunk_exec_time(8, 64, 8, QuantMode::Fp16);
        let token_at_a_time = 8.0 * cm.expert_exec_time(8, 8, QuantMode::Fp16);
        assert!(chunked < token_at_a_time, "chunked {chunked} >= sequential {token_at_a_time}");
        // ...but per-assignment MXU compute is not amortized away
        let more_assignments = cm.chunk_exec_time(8, 128, 8, QuantMode::Fp16);
        assert!(more_assignments > chunked);
    }

    #[test]
    fn gpus_ordered_by_speed() {
        let dims = olmoe_dims();
        let t = |g: GpuSpec| CostModel::new(g, dims).ideal_token_time();
        assert!(t(GpuSpec::h100()) < t(GpuSpec::a100()));
        assert!(t(GpuSpec::a100()) < t(GpuSpec::rtx4090()));
    }

    #[test]
    fn by_name() {
        assert_eq!(GpuSpec::by_name("h100").unwrap().name, "h100");
        assert!(GpuSpec::by_name("tpu").is_err());
    }
}
