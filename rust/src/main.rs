//! `melinoe` — leader entrypoint / CLI.
//!
//! Subcommands:
//!   repro <id|all>   regenerate a paper table/figure (DESIGN.md §4)
//!   serve            step-level serving loop (continuous batching) over
//!                    an eval workload
//!   cluster          multi-replica serving simulation
//!   decode           decode one eval prompt and print everything
//!   info             show artifact/config inventory

use anyhow::{anyhow, Result};
use melinoe::clock::GpuSpec;
use melinoe::cluster;
use melinoe::cluster::workload::{OutputLen, PriorityMix, StreamMix};
use melinoe::coordinator::workload::Arrival;
use melinoe::coordinator::{
    Decoder, Outcome, PreemptPolicy, RequestSpec, SchedulerMode, SeqFinish, Server, ServerConfig,
    StreamPolicy,
};
use melinoe::fault::{FaultSpec, RetryPolicy};
use melinoe::engine::{DecodeSession, Engine, SeqState};
use melinoe::metrics::{fmt2, Table};
use melinoe::policies::PolicyConfig;
use melinoe::quant::QuantMode;
use melinoe::repro::{Ctx, EngineParts};
use melinoe::util::cli::Args;
use melinoe::util::rng::Rng;

const USAGE: &str = "melinoe — memory-efficient MoE serving (MELINOE reproduction)

usage: melinoe <command> [options]

commands:
  repro <id|all>     regenerate a paper table/figure
                     (table1 fig1a fig1b fig3 table2 table3 fig4 fig5 table4
                      table5 table11 fig6 heatmaps fig11 table12 fig12 fig13
                      table13 ext_layerwise ext_cluster ext_continuous
                      ext_prefill ext_overlap ext_preempt ext_quant
                      ext_stream ext_fault ext_steal)
  serve              step-level serving loop over the eval workload
  cluster            multi-replica serving simulation: compare balancers
  decode             decode one prompt, print tokens + transfer stats
  trace summary <f>  render counter / expert-churn / stall tables from a
                     --trace JSON export (--top <n> rows, default 10)
  info               artifact inventory

common options:
  --preset <name>    olmoe-micro | phi-micro | mixtral-micro
  --gpu <name>       h100 | a100 | rtx4090
  --policy <name>    melinoe | fiddler | mixtral-offloading | deepspeed-moe
                     | floe | moe-infinity | base
  --variant <v>      checkpoint variant (default: policy's own)
  --prompts <n>      eval prompts per configuration
  --tokens <n>       max output tokens
  --requests <n>     serve/cluster: total requests to submit
  --batch <n>        serve/cluster: decode slots per engine/replica
  --scheduler <m>    serve/cluster: continuous (step-level admission,
                     default) | static (run-to-completion batches)
  --prefill-chunk <n> serve/cluster: prompt tokens a prefilling sequence
                     consumes per step, piggybacked on live decodes
                     (default 1 = token-at-a-time; 8-32 cuts long-prompt
                     TTFT, see docs/SERVING.md)
  --lookahead <d>    serve/cluster: layer-ahead transfer pipeline — during
                     layer l's compute, prefetch the next d layers'
                     predicted experts non-blocking; a decode catching a
                     transfer on the link pays only the residual wait
                     (default 0 = admit-time prefetch only)
  --preempt <p>      serve/cluster: off (default) or a threshold in
                     simulated seconds — once a higher-priority request
                     has waited longer for a slot, the lowest-priority
                     in-flight sequence is suspended at a step boundary
                     and resumed later, bit-identically (docs/SERVING.md)
  --high-frac <f>    serve/cluster: fraction of requests submitted High
                     priority (default 0)
  --low-frac <f>     serve/cluster: fraction of requests submitted Low
                     priority (default 0; the rest are Normal)
  --deadline-mix <f> serve/cluster: fraction of requests carrying a TTFT
                     deadline (default 0); goodput counts only completed
                     requests whose first token met their deadline
  --deadline-slack <s>
                     serve/cluster: the deadline granted to deadline-mix
                     requests, simulated seconds from arrival (default 1)
  --cancel-after <n> serve/cluster: cancelling clients hang up after
                     consuming n tokens (0 = off); the request terminates
                     Cancelled with its partial output, slot and pins
                     reclaimed at the step boundary
  --cancel-frac <f>  serve/cluster: fraction of requests that cancel when
                     --cancel-after is set (default 1)
  --disconnect-rate <f>
                     serve/cluster: fraction of clients that disconnect
                     while still queued — never admitted, counted as
                     cancelled-in-queue (default 0)
  --admission        serve/cluster: SLO-aware admission control — reject
                     deadline requests whose estimated TTFT already
                     misses, instead of serving them to a p99 miss
  --trace <file>     serve/cluster: record the structured sim-time event
                     stream and write a Chrome/Perfetto trace JSON (open
                     in ui.perfetto.dev; one lane per replica plus a
                     dispatcher lane; docs/OBSERVABILITY.md)
  --quant <t>        serve/cluster/decode: precision tier resident experts
                     are stored and executed at — fp16 | int4 | int3
                     (default: the policy's / replica spec's own tier);
                     lower tiers shrink per-expert bytes, so the same
                     VRAM budget holds proportionally more experts and
                     PCIe transfers cost proportionally less
  --little-tier <t>  serve/cluster: keep low-bit \"little\" copies of the
                     hottest experts resident alongside the --quant
                     copies; must be strictly fewer bits than --quant
                     (enables the big-little fallback, docs/SERVING.md)
  --fallback-threshold <s>
                     serve/cluster: expected transfer wait (simulated
                     seconds) above which a demand miss executes the
                     resident little copy at zero stall instead of
                     waiting (default 0 = any wait falls back); degraded
                     executions surface as degraded_token_frac

cluster options:
  --replicas <n>     fleet size (default 4)
  --tasks <n>        heterogeneous traffic streams (default 4)
  --balancer <name>  round-robin | least-loaded | expert-affinity
                     | priority-affinity | all (all = the stock three)
  --rate <r>         Poisson arrival rate req/s (0 = auto ≈1.5× capacity)
  --burst            all requests arrive at t=0 (saturation test)
  --long-frac <f>    fraction of requests decoding the full --tokens
                     budget; the rest stop at --tokens/8 (0 = uniform)
  --seed <n>         workload seed
  --faults <mode>    fault injection: off (default) | crash (fail-stop
                     crash storm) | mixed (crashes + brownouts + link
                     flaps + transfer corruption); the plan is drawn
                     from its own seed lane, so --faults off stays
                     byte-identical to a build without the fault module
                     (docs/ROBUSTNESS.md)
  --mtbf <s>         mean sim-seconds between injected faults (default:
                     sized from the workload so a run sees a handful)
  --retry <n>        per-request retry budget after a replica failure
                     (default 0 = a reclaimed request terminates
                     Failed); retries re-dispatch with exponential
                     backoff and bit-identical continuation
  --steal            fleet-scale work stealing: idle replicas steal
                     queued and suspended work from loaded peers,
                     priced by warm-cache affinity vs queue delay vs
                     KV migration cost (docs/CLUSTER.md)
  --steal-interval <s>
                     sim-seconds between steal scans (default: a
                     quarter of the per-request service estimate);
                     setting it implies --steal
  --age-promote <s>  age-based priority promotion threshold τ: a
                     request waiting ≥ τ is promoted to Normal, ≥ 2τ
                     to High, bounding Low-priority starvation under
                     a sustained High flood (default off)
";

fn policy_by_name(name: &str, cap: usize, top_k: usize, ft: &str) -> Result<PolicyConfig> {
    Ok(match name {
        "melinoe" => PolicyConfig::melinoe(ft, cap),
        "melinoe-np" => PolicyConfig::melinoe_no_prefetch(ft, cap),
        "fiddler" => PolicyConfig::fiddler(cap),
        "mixtral-offloading" | "mixoff" => PolicyConfig::mixtral_offloading(cap),
        "deepspeed-moe" | "deepspeed" => PolicyConfig::deepspeed_moe(top_k),
        "floe" => PolicyConfig::floe(cap),
        "moe-infinity" | "moeinf" => PolicyConfig::moe_infinity(cap),
        "base" => PolicyConfig::base_offload(cap),
        _ => return Err(anyhow!("unknown policy {name:?}")),
    })
}

/// Parse the precision flags shared by `serve` and `cluster`, resolving
/// an omitted `--quant` to `default_quant` (each policy / replica spec
/// carries its own serving tier, so the flag is an *override*, not a
/// reset).  Surfaces `QuantMode::parse` errors (which list the valid
/// tiers) verbatim, and rejects a `--little-tier` that is not strictly
/// smaller than the effective serving tier.
fn quant_args(
    args: &Args,
    default_quant: QuantMode,
) -> Result<(QuantMode, Option<QuantMode>, f64)> {
    let quant = match args.get("quant") {
        Some(q) => QuantMode::parse(q)?,
        None => default_quant,
    };
    let little = match args.get("little-tier") {
        Some(l) => {
            let lt = QuantMode::parse(l)?;
            melinoe::quant::validate_little_tier(quant, lt)?;
            Some(lt)
        }
        None => None,
    };
    let threshold = args.get_f64("fallback-threshold", 0.0)?.max(0.0);
    Ok((quant, little, threshold))
}

/// Parse the streaming-workload flags shared by `serve` and `cluster`
/// into a [`StreamMix`] plus the admission toggle — one builder path for
/// both subcommands, so the knobs can never drift apart.  With every
/// flag omitted the mix is [`StreamMix::none`] and workloads (and decode
/// numerics) are bit-identical to a pre-streaming build.
fn stream_args(args: &Args) -> Result<(StreamMix, bool)> {
    let deadline_frac = args.get_f64("deadline-mix", 0.0)?.clamp(0.0, 1.0);
    let deadline_slack = args.get_f64("deadline-slack", 1.0)?.max(0.0);
    let cancel_after = args.get_usize("cancel-after", 0)?;
    let cancel_frac = if cancel_after > 0 {
        args.get_f64("cancel-frac", 1.0)?.clamp(0.0, 1.0)
    } else {
        0.0
    };
    let disconnect_frac = args.get_f64("disconnect-rate", 0.0)?.clamp(0.0, 1.0);
    let mix =
        StreamMix { deadline_frac, deadline_slack, cancel_frac, cancel_after, disconnect_frac };
    Ok((mix, args.has_flag("admission")))
}

/// Owns everything the serving thread needs (constructed in-thread; PJRT
/// handles are not Send).  The persistent `DecodeSession` carries the
/// in-flight sequences, expert cache and simulated clock across step
/// calls; the borrowing `Engine` view is rebuilt per call.
struct OwnedEngine {
    ctx: Ctx,
    parts: EngineParts,
    gpu: GpuSpec,
    sess: DecodeSession,
}

impl OwnedEngine {
    fn new(ctx: Ctx, parts: EngineParts, gpu: GpuSpec) -> OwnedEngine {
        let sess = parts.engine(&ctx, gpu.clone()).session();
        OwnedEngine { ctx, parts, gpu, sess }
    }
}

impl Decoder for OwnedEngine {
    fn admit(&mut self, prompt: &[usize], max_output: usize) -> Result<u64> {
        let engine: Engine = self.parts.engine(&self.ctx, self.gpu.clone());
        engine.admit(&mut self.sess, prompt, max_output)
    }

    fn step(&mut self) -> Result<Vec<SeqFinish>> {
        let engine: Engine = self.parts.engine(&self.ctx, self.gpu.clone());
        engine.step(&mut self.sess)
    }

    fn active(&self) -> usize {
        self.sess.active()
    }

    fn now(&self) -> f64 {
        self.sess.now()
    }

    fn set_prefill_chunk(&mut self, chunk: usize) {
        self.sess.set_prefill_chunk(chunk);
    }

    fn transfer_stats(&self) -> melinoe::pcie::TransferStats {
        self.sess.pcie.stats.clone()
    }

    fn suspend(&mut self, seq: u64) -> Result<Box<dyn std::any::Any>> {
        let engine: Engine = self.parts.engine(&self.ctx, self.gpu.clone());
        Ok(Box::new(engine.suspend(&mut self.sess, seq)?))
    }

    fn resume(&mut self, state: Box<dyn std::any::Any>) -> Result<u64> {
        let st = state
            .downcast::<SeqState>()
            .map_err(|_| anyhow!("foreign suspended state handed to the engine"))?;
        let engine: Engine = self.parts.engine(&self.ctx, self.gpu.clone());
        engine.resume(&mut self.sess, *st)
    }

    fn cancel(&mut self, seq: u64) -> Result<Vec<usize>> {
        let engine: Engine = self.parts.engine(&self.ctx, self.gpu.clone());
        let st = engine.cancel(&mut self.sess, seq)?;
        Ok(st.tokens)
    }

    fn peek_tokens(&self, seq: u64) -> Vec<usize> {
        self.sess.emitted_tokens(seq)
    }

    fn note(&mut self, ev: melinoe::trace::TraceEvent) {
        self.sess.note(ev);
    }

    fn set_tracing(&mut self, on: bool) {
        self.sess.set_tracing(on);
    }

    fn take_trace(&mut self) -> Option<melinoe::trace::Trace> {
        self.sess.take_trace()
    }

    fn degraded_token_frac(&self) -> f64 {
        self.sess.degraded_token_frac()
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "olmoe-micro").to_string();
    let gpu = GpuSpec::by_name(args.get_or("gpu", "h100"))?;
    let policy_name = args.get_or("policy", "melinoe").to_string();
    let n_requests = args.get_usize("requests", 12)?;
    let max_output = args.get_usize("tokens", 24)?;
    let max_batch = args.get_usize("batch", 4)?;
    let scheduler = SchedulerMode::parse(args.get_or("scheduler", "continuous"))?;
    let prefill_chunk = args.get_usize("prefill-chunk", 1)?.max(1);
    let has_lookahead = args.get("lookahead").is_some();
    let lookahead = args.get_usize("lookahead", 0)?;
    let preempt = PreemptPolicy::parse(args.get_or("preempt", "off"))?;
    let high_frac = args.get_f64("high-frac", 0.0)?.clamp(0.0, 1.0);
    let low_frac = args.get_f64("low-frac", 0.0)?.clamp(0.0, 1.0 - high_frac);
    let seed = args.get_usize("seed", 42)? as u64;
    let ds = args.get_or("dataset", "dolly").to_string();
    let trace_path = args.get("trace").map(str::to_string);
    let (smix, admission) = stream_args(args)?;

    // load the prompts up-front (the server thread owns the engine)
    let ctx0 = Ctx::load(&melinoe::artifacts_dir(), &preset)?;
    let eval = ctx0.eval_set(&ds)?;
    // resolve --quant/--little-tier against the policy's own serving
    // tier (a probe config: the real policy is built on the server
    // thread), so omitting --quant keeps each baseline's native tier
    let ft0 = if ds == "dolly" { "ft_dolly" } else { "ft_gsm" };
    let default_quant =
        policy_by_name(&policy_name, ctx0.cfg.cache_capacity, ctx0.cfg.top_k, ft0)?.quant;
    let (quant, little, fallback_threshold) = quant_args(args, default_quant)?;
    let prompts: Vec<Vec<usize>> = eval
        .samples
        .iter()
        .cycle()
        .take(n_requests)
        .map(|s| s.prompt.clone())
        .collect();
    drop(ctx0);

    let gpu2 = gpu.clone();
    let ds2 = ds.clone();
    let server = Server::start(
        move || -> Result<OwnedEngine> {
            let ctx = Ctx::load(&melinoe::artifacts_dir(), &preset)?;
            let ft = if ds2 == "dolly" { "ft_dolly" } else { "ft_gsm" };
            let mut policy =
                policy_by_name(&policy_name, ctx.cfg.cache_capacity, ctx.cfg.top_k, ft)?;
            // an explicit `--lookahead 0` still swaps in lookahead's
            // admit-plan source (predictor, else profile), so comparing
            // `--lookahead 0` vs `--lookahead 1` isolates the pipeline
            // itself rather than also changing the admit-time plan
            if has_lookahead {
                policy = policy.with_lookahead(lookahead);
            }
            policy = policy.with_quant(quant).with_fallback(little, fallback_threshold);
            let parts = ctx.parts(&policy, &ds2)?;
            Ok(OwnedEngine::new(ctx, parts, gpu2))
        },
        ServerConfig::default()
            .with_max_batch(max_batch)
            .with_batch_wait(std::time::Duration::from_millis(5))
            .with_max_output(max_output)
            .with_scheduler(scheduler)
            .with_prefill_chunk(prefill_chunk)
            .with_preempt(preempt)
            .with_trace(trace_path.is_some())
            .with_stream(StreamPolicy::default().with_admission(admission)),
    );

    let t0 = std::time::Instant::now();
    let mix = PriorityMix { high: high_frac, low: low_frac };
    let mut prio_rng = Rng::new(seed);
    let mut stream_rng = Rng::new(seed ^ 0x00c0_ffee);
    let streams: Vec<_> = prompts
        .into_iter()
        .map(|p| {
            let mut spec =
                RequestSpec::new(p).max_output(max_output).priority(mix.draw(&mut prio_rng));
            let (deadline, cancel_after, disconnect) = smix.draw(&mut stream_rng, 0.0);
            if let Some(d) = deadline {
                spec = spec.deadline(d);
            }
            if let Some(n) = cancel_after {
                spec = spec.cancel_after(n);
            }
            let stream = server.submit(spec);
            if disconnect {
                // the client vanishes before consuming anything; the
                // handle is kept only to collect the terminal outcome
                stream.cancel();
            }
            stream
        })
        .collect();
    let mut total_tokens = 0usize;
    for stream in streams {
        let resp = stream.wait()?;
        if resp.outcome == Outcome::Completed {
            total_tokens += resp.tokens.len();
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown()?;
    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["scheduler".into(), format!("{scheduler:?}").to_lowercase()]);
    t.row(vec!["prefill chunk".into(), stats.prefill_chunk.to_string()]);
    t.row(vec![
        "lookahead".into(),
        // `--lookahead 0` is a distinct configuration from omitting the
        // flag (it swaps the admit-plan source; docs/SERVING.md)
        if has_lookahead { lookahead.to_string() } else { "- (policy native)".into() },
    ]);
    t.row(vec!["requests".into(), stats.requests.to_string()]);
    t.row(vec![
        "completed / cancelled / rejected".into(),
        format!("{} / {} / {}", stats.completed, stats.cancelled, stats.rejected),
    ]);
    t.row(vec!["cancelled in queue".into(), stats.cancelled_in_queue.to_string()]);
    t.row(vec![
        "admission".into(),
        if admission { "slo-aware".into() } else { "off".to_string() },
    ]);
    t.row(vec!["token steps".into(), stats.steps.to_string()]);
    t.row(vec!["mean slot occupancy".into(), fmt2(stats.mean_batch_size)]);
    t.row(vec!["output tokens".into(), total_tokens.to_string()]);
    t.row(vec![
        "sim throughput tok/s".into(),
        fmt2(total_tokens as f64 / stats.total_sim_seconds.max(1e-9)),
    ]);
    t.row(vec!["goodput tok/s".into(), fmt2(stats.goodput())]);
    t.row(vec!["ttft p50/p95/p99 (s)".into(), stats.ttft.cell(1.0)]);
    t.row(vec!["tpot p50/p95/p99 (ms)".into(), stats.tpot.cell(1e3)]);
    t.row(vec!["sim latency p50/p95/p99 (s)".into(), stats.sim_latency.cell(1.0)]);
    t.row(vec!["queue wait p50/p95/p99 (ms)".into(), stats.queue_wait.cell(1e3)]);
    t.row(vec![
        "preempt".into(),
        match preempt {
            PreemptPolicy::Off => "off".into(),
            PreemptPolicy::After(s) => format!("after {s}s wait"),
        },
    ]);
    t.row(vec!["preemptions".into(), stats.preemptions.to_string()]);
    t.row(vec!["preempted wait p50/p95/p99 (ms)".into(), stats.preempted_wait.cell(1e3)]);
    t.row(vec!["pcie stall (s)".into(), fmt2(stats.pcie_stall_seconds)]);
    t.row(vec!["pcie overlap frac".into(), format!("{:.3}", stats.pcie_overlap_fraction)]);
    t.row(vec!["quant".into(), quant.name().into()]);
    t.row(vec![
        "little tier / fallback".into(),
        match little {
            Some(lt) => format!("{} / {}s", lt.name(), fallback_threshold),
            None => "off".into(),
        },
    ]);
    t.row(vec!["degraded token frac".into(), format!("{:.4}", stats.degraded_token_frac)]);
    t.row(vec!["wall seconds".into(), fmt2(wall)]);
    println!("{}", t.render());
    if let Some(path) = &trace_path {
        match &stats.trace {
            Some(tr) => {
                std::fs::write(path, tr.to_chrome_json().to_string())
                    .map_err(|e| anyhow!("write {path}: {e}"))?;
                println!("trace: {} events -> {path}", tr.events.len());
            }
            None => println!("trace: engine recorded no events"),
        }
    }
    Ok(())
}

fn cmd_decode(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "olmoe-micro");
    let gpu = GpuSpec::by_name(args.get_or("gpu", "h100"))?;
    let ds = args.get_or("dataset", "dolly");
    let idx = args.get_usize("index", 0)?;
    let max_output = args.get_usize("tokens", 32)?;
    let ctx = Ctx::load(&melinoe::artifacts_dir(), preset)?;
    let ft = if ds == "dolly" { "ft_dolly" } else { "ft_gsm" };
    let mut policy =
        policy_by_name(args.get_or("policy", "melinoe"), ctx.cfg.cache_capacity, ctx.cfg.top_k, ft)?;
    if let Some(v) = args.get("variant") {
        policy = policy.with_variant(v);
    }
    if let Some(q) = args.get("quant") {
        policy = policy.with_quant(QuantMode::parse(q)?);
    }
    let parts = ctx.parts(&policy, ds)?;
    let engine = parts.engine(&ctx, gpu);
    let eval = ctx.eval_set(ds)?;
    let sample = &eval.samples[idx.min(eval.samples.len() - 1)];
    let out = engine.decode(&sample.prompt, max_output)?;
    println!("policy     : {} (variant {})", policy.name, policy.variant);
    println!("prompt     : {:?}", sample.prompt);
    println!("generated  : {:?}", out.tokens);
    println!("reference  : {:?}", sample.reference);
    println!("rouge-l    : {:.4}", melinoe::eval::rouge_l(&out.tokens, &sample.reference));
    println!(
        "sim time   : {:.3}s  ({:.2} tok/s)",
        out.metrics.sim_seconds,
        out.metrics.tokens_per_sec()
    );
    println!("wall time  : {:.3}s", out.metrics.wall_seconds);
    println!(
        "transfers  : h2d={} d2h={}  tx/layer={:.1}  hit-rate={:.3}",
        out.report.transfers.h2d_count,
        out.report.transfers.d2h_count,
        out.report.misses_per_layer,
        out.report.cache.hit_rate()
    );
    println!("cpu execs  : {}   sparsity skips: {}", out.cpu_execs, out.sparsity_skips);
    println!("top-C share: {:.3}", out.trace.mean_topc_share(ctx.cfg.cache_capacity));
    Ok(())
}

/// Multi-replica serving simulation (no artifacts required — cost model +
/// synthetic per-task routing traces, see docs/CLUSTER.md).
fn cmd_cluster(args: &Args) -> Result<()> {
    let replicas = args.get_usize("replicas", 4)?;
    let n_requests = args.get_usize("requests", 64)?;
    let n_tasks = args.get_usize("tasks", 4)?;
    let max_batch = args.get_usize("batch", 4)?;
    let tokens = args.get_usize("tokens", 24)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let gpu = GpuSpec::by_name(args.get_or("gpu", "h100"))?;
    let rate = args.get_f64("rate", 0.0)?;
    let long_frac = args.get_f64("long-frac", 0.0)?.clamp(0.0, 1.0);
    let scheduler = SchedulerMode::parse(args.get_or("scheduler", "continuous"))?;
    let prefill_chunk = args.get_usize("prefill-chunk", 1)?.max(1);
    let lookahead = args.get_usize("lookahead", 0)?;
    let preempt = PreemptPolicy::parse(args.get_or("preempt", "off"))?;
    let high_frac = args.get_f64("high-frac", 0.0)?.clamp(0.0, 1.0);
    let low_frac = args.get_f64("low-frac", 0.0)?.clamp(0.0, 1.0 - high_frac);
    let (smix, admission) = stream_args(args)?;
    let mut b = cluster::ClusterConfig::builder(replicas, n_requests, n_tasks, gpu, seed)
        .scheduler(scheduler)
        .prefill_chunk(prefill_chunk)
        .lookahead(lookahead)
        .preempt(preempt)
        .priority_mix(PriorityMix { high: high_frac, low: low_frac })
        .stream_mix(smix)
        .admission(admission)
        .max_batch(max_batch)
        .output(if long_frac > 0.0 {
            OutputLen::Bimodal { short: (tokens / 8).max(1), long: tokens, long_frac }
        } else {
            OutputLen::Fixed(tokens)
        })
        .trace(args.get("trace").is_some());
    // resolve --quant against the spec's own serving tier, so omitting
    // the flag keeps the VRAM-derived default; .quant() preserves the
    // byte budget by rescaling the per-layer slot count
    let (quant, little, fallback_threshold) = quant_args(args, b.draft().spec.quant)?;
    b = b.quant(quant).fallback(little, fallback_threshold);
    // re-derive the service estimate for the overridden token budget so
    // the auto rate stays ≈1.5× fleet capacity
    let draft = b.draft();
    let est = draft
        .spec
        .est_service_seconds(
            draft.workload.prompt_tokens,
            draft.workload.output.mean().ceil().max(1.0) as usize,
        )
        .max(1e-6);
    b = if args.has_flag("burst") {
        b.arrival(Arrival::Burst)
    } else if rate > 0.0 {
        b.arrival(Arrival::Poisson(rate))
    } else {
        let fleet = b.draft().replicas as f64;
        b.arrival(Arrival::Poisson(1.5 * fleet / est))
    };
    // fault plan + retry budget; the horizon spans the expected run so
    // --mtbf defaults to "a handful of faults per run"
    let faults_mode = args.get_or("faults", "off").to_string();
    let horizon = (n_requests as f64 * est / b.draft().replicas.max(1) as f64).max(est);
    let mtbf = args.get_f64("mtbf", horizon / 2.5)?.max(1e-6);
    let fspec = match faults_mode.as_str() {
        "off" => FaultSpec::none(),
        "crash" => FaultSpec::crash_storm(mtbf, horizon, est / 4.0),
        "mixed" => FaultSpec::mixed(mtbf, horizon, est),
        other => return Err(anyhow!("unknown --faults {other:?} (off | crash | mixed)")),
    };
    let retry_budget = args.get_usize("retry", 0)? as u32;
    let retry = if retry_budget > 0 {
        RetryPolicy::retries(retry_budget, est / 8.0)
    } else {
        RetryPolicy::off()
    };
    b = b.faults(fspec).retry(retry);
    // fleet-scale work stealing + age-based promotion (docs/CLUSTER.md);
    // the interval defaults to a quarter of the per-request estimate so
    // an idle replica scans a few times per service time
    if args.has_flag("steal") || args.get("steal-interval").is_some() {
        let interval = args.get_f64("steal-interval", est / 4.0)?;
        b = b.steal(Some(cluster::StealPolicy::every(interval)));
    }
    let tau = args.get_f64("age-promote", 0.0)?;
    if tau != 0.0 {
        b = b.age_promote(Some(tau));
    }
    let cfg = b.build()?;
    let arrival_desc = match cfg.workload.arrival {
        Arrival::Burst => "burst".to_string(),
        Arrival::Poisson(r) => format!("poisson {r:.2} req/s"),
        Arrival::Uniform(g) => format!("uniform {g:.3}s gap"),
    };
    let tiers_desc = match cfg.spec.little_tier {
        Some(lt) => {
            format!("{} + little {} @ {}s", quant.name(), lt.name(), cfg.spec.fallback_threshold)
        }
        None => quant.name().to_string(),
    };
    println!(
        "cluster: {} replicas × C={} experts/layer, {} requests over {} tasks ({}), \
         {} slots/replica, {:?} scheduler, prefill chunk {}, lookahead {}, quant {}",
        cfg.replicas, cfg.spec.capacity, n_requests, n_tasks, arrival_desc, cfg.max_batch,
        scheduler, cfg.prefill_chunk, cfg.spec.lookahead, tiers_desc
    );
    if !cfg.workload.stream.is_none() || cfg.admission {
        let s = &cfg.workload.stream;
        println!(
            "  stream: deadline {:.0}% @ {:.2}s slack, cancel {:.0}% after {} tok, \
             disconnect {:.0}%, admission {}",
            100.0 * s.deadline_frac,
            s.deadline_slack,
            100.0 * s.cancel_frac,
            s.cancel_after,
            100.0 * s.disconnect_frac,
            if cfg.admission { "slo-aware" } else { "off" }
        );
    }
    if cfg.faults.enabled {
        println!(
            "  faults: {} (mtbf {:.2}s over {:.2}s horizon), retry budget {} \
             (backoff {:.3}s, exponential)",
            faults_mode, cfg.faults.mtbf, cfg.faults.horizon, cfg.retry.max_retries,
            cfg.retry.backoff
        );
    }
    if cfg.steal.is_some() || cfg.age_promote.is_some() {
        let steal_desc = match &cfg.steal {
            Some(s) => format!(
                "every {:.4}s (load coeff {}, live {})",
                s.interval, s.load_coeff, s.live
            ),
            None => "off".to_string(),
        };
        let age_desc = match cfg.age_promote {
            Some(t) => format!("{t:.4}s"),
            None => "off".to_string(),
        };
        println!("  steal: {steal_desc}, age-promote {age_desc}");
    }

    let which = args.get_or("balancer", "all");
    let names: Vec<&str> =
        if which == "all" { cluster::BALANCERS.to_vec() } else { vec![which] };
    let reports = cluster::compare(&cfg, &names)?;
    println!("{}", cluster::comparison_table(&reports).render());
    for r in &reports {
        let depths: Vec<String> =
            r.replicas.iter().map(|s| s.peak_queue_depth.to_string()).collect();
        println!(
            "  {}: makespan {:.2}s, pcie stall {:.2}s, overlap frac {:.3}, \
             preemptions {}, peak queue depths [{}]",
            r.balancer,
            r.makespan,
            r.stall_seconds,
            r.overlap_fraction,
            r.preemptions,
            depths.join(", ")
        );
        if r.cancelled > 0 || r.rejected > 0 || r.failed > 0 {
            println!(
                "    outcomes: {} completed, {} cancelled, {} rejected, {} failed; \
                 goodput {:.2} tok/s (deadline-attained output only)",
                r.completed, r.cancelled, r.rejected, r.failed, r.goodput_per_sec
            );
        }
        if r.injected > 0 {
            println!(
                "    faults: {} sequences reclaimed ({} recovered, {} failed), \
                 {} retries, {} migrations, recovery wait p50/p95/p99 {}s",
                r.injected,
                r.recovered,
                r.failed,
                r.retries,
                r.migrations,
                r.recovery_wait.cell(1.0)
            );
        }
        if r.steals > 0 || r.promotions > 0 {
            println!(
                "    steal/aging: {} steals ({} live migrations), {} promotions",
                r.steals, r.live_steals, r.promotions
            );
        }
        if r.priorities.len() > 1 {
            for pc in &r.priorities {
                println!(
                    "    {:>6}: {} reqs, ttft p50/p95/p99 {}s, latency p50/p95/p99 {}s, \
                     preempted wait p95 {:.3}s",
                    pc.priority.name(),
                    pc.requests,
                    pc.ttft.cell(1.0),
                    pc.latency.cell(1.0),
                    pc.preempted_wait.p95
                );
            }
        }
    }
    if let Some(path) = args.get("trace") {
        // `compare` reuses one path per balancer; export the last run's
        // timeline (replica lanes + dispatcher lane)
        match reports.iter().rev().find_map(|r| r.trace.as_ref().map(|t| (&r.balancer, t))) {
            Some((name, tr)) => {
                std::fs::write(path, tr.to_chrome_json().to_string())
                    .map_err(|e| anyhow!("write {path}: {e}"))?;
                println!("trace ({name}): {} events -> {path}", tr.events.len());
            }
            None => println!("trace: no events recorded"),
        }
    }
    Ok(())
}

/// `trace summary <file>`: render the metrics registry embedded in a
/// `--trace` export (counters, top-N expert churn, stalls by layer).
fn cmd_trace(args: &Args) -> Result<()> {
    let usage = "usage: melinoe trace summary <trace.json> [--top <n>]";
    if args.positional.get(1).map(String::as_str) != Some("summary") {
        return Err(anyhow!("{usage}"));
    }
    let path = args.positional.get(2).ok_or_else(|| anyhow!("{usage}"))?;
    let top = args.get_usize("top", 10)?;
    let j = melinoe::util::json::Json::from_file(path)?;
    let reg = j
        .opt("melinoe")
        .ok_or_else(|| {
            anyhow!("{path}: no \"melinoe\" registry snapshot (not a --trace export?)")
        })?;
    for (title, table) in melinoe::trace::summary_tables(reg, top)? {
        println!("{title}");
        println!("{}", table.render());
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = melinoe::artifacts_dir();
    let mut t = Table::new(&["preset", "L", "E", "K", "d", "dff", "C", "variants"]);
    for preset in ["olmoe-micro", "phi-micro", "mixtral-micro"] {
        match Ctx::load(&dir, preset) {
            Ok(ctx) => {
                t.row(vec![
                    preset.into(),
                    ctx.cfg.n_layers.to_string(),
                    ctx.cfg.n_experts.to_string(),
                    ctx.cfg.top_k.to_string(),
                    ctx.cfg.d_model.to_string(),
                    ctx.cfg.d_ff.to_string(),
                    ctx.cfg.cache_capacity.to_string(),
                    ctx.cfg.variants.len().to_string(),
                ]);
            }
            Err(e) => {
                t.row(vec![
                    preset.into(),
                    format!("unavailable: {e}"),
                    String::new(),
                    String::new(),
                    String::new(),
                    String::new(),
                    String::new(),
                    String::new(),
                ]);
            }
        }
    }
    let _ = args;
    println!("{}", t.render());
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    if args.positional.is_empty() || args.has_flag("help") {
        println!("{USAGE}");
        return Ok(());
    }
    match args.positional[0].as_str() {
        "repro" => {
            let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            melinoe::repro::run(id, &args)
        }
        "serve" => cmd_serve(&args),
        "cluster" => cmd_cluster(&args),
        "decode" => cmd_decode(&args),
        "trace" => cmd_trace(&args),
        "info" => cmd_info(&args),
        other => Err(anyhow!("unknown command {other:?}\n{USAGE}")),
    }
}
