// Throwaway smoke: load every lowered artifact, compile on PJRT CPU, run
// layer_step + expert_group with random inputs, print output shapes.
use anyhow::Result;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap();
    let client = xla::PjRtClient::cpu()?;
    for name in ["layer_step", "expert_group", "lm_head", "predictor"] {
        let path = format!("{dir}/hlo/{name}.hlo.txt");
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        println!("{name}: compiled ok");
        if name == "expert_group" {
            let (k, d, dff) = (8usize, 32usize, 64usize);
            let gates = xla::Literal::vec1(&vec![0.125f32; k]);
            let h2 = xla::Literal::vec1(&vec![0.1f32; d]);
            let wg = xla::Literal::vec1(&vec![0.01f32; k*dff*d]).reshape(&[k as i64, dff as i64, d as i64])?;
            let wu = wg.reshape(&[k as i64, dff as i64, d as i64])?;
            let wd = xla::Literal::vec1(&vec![0.01f32; k*d*dff]).reshape(&[k as i64, d as i64, dff as i64])?;
            let r = exe.execute::<xla::Literal>(&[gates, h2, wg, wu, wd])?[0][0].to_literal_sync()?;
            let out = r.to_tuple1()?;
            println!("  expert_group out: {:?} first={:?}", out.array_shape()?, out.to_vec::<f32>()?[0]);
        }
        if name == "layer_step" {
            let (d, e, h, t, hd) = (32usize, 64usize, 4usize, 288usize, 8usize);
            let v1 = |n: usize| xla::Literal::vec1(&vec![0.05f32; n]);
            let dd = v1(d*d).reshape(&[d as i64, d as i64])?;
            let kv = v1(h*t*hd).reshape(&[h as i64, t as i64, hd as i64])?;
            let args = vec![
                v1(d), v1(d),
                dd.reshape(&[d as i64, d as i64])?, dd.reshape(&[d as i64, d as i64])?,
                dd.reshape(&[d as i64, d as i64])?, dd.reshape(&[d as i64, d as i64])?,
                v1(d), v1(e*d).reshape(&[e as i64, d as i64])?,
                kv.reshape(&[h as i64, t as i64, hd as i64])?, kv.reshape(&[h as i64, t as i64, hd as i64])?,
                xla::Literal::scalar(0i32),
            ];
            let r = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
            let outs = r.to_tuple()?;
            println!("  layer_step outputs: {}", outs.len());
            for (i, o) in outs.iter().enumerate() {
                println!("    out{i}: {:?}", o.array_shape()?);
            }
        }
    }
    println!("hlo_smoke OK");
    Ok(())
}
