//! Criterion-style micro-bench harness (criterion is unavailable offline).
//!
//! Usage from a `harness = false` bench target:
//! ```ignore
//! let mut b = Bench::new("cache");
//! b.bench("lfu_request", || { ... });
//! b.finish();
//! ```
//! Each case is warmed up, then timed over adaptively-chosen iteration
//! batches until the target measurement time is reached; mean / median /
//! p95 and a throughput estimate are printed.

use std::time::{Duration, Instant};

pub struct Bench {
    group: String,
    target: Duration,
    results: Vec<CaseResult>,
}

#[derive(Debug, Clone)]
pub struct CaseResult {
    pub name: String,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub iters: u64,
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        let target = std::env::var("BENCH_TARGET_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .map(Duration::from_millis)
            .unwrap_or(Duration::from_millis(800));
        println!("\n== bench group: {group} ==");
        Bench { group: group.to_string(), target, results: Vec::new() }
    }

    /// Time `f`; `f` should perform one logical operation.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &CaseResult {
        // warmup + calibration
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed() < Duration::from_millis(50) {
            f();
            calib_iters += 1;
        }
        let per_iter = t0.elapsed().as_nanos() as f64 / calib_iters as f64;
        // choose batch so each sample is ~1/20 of target
        let sample_ns = self.target.as_nanos() as f64 / 20.0;
        let batch = ((sample_ns / per_iter).ceil() as u64).max(1);
        let mut samples: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let run_start = Instant::now();
        while run_start.elapsed() < self.target || samples.len() < 5 {
            let s = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(s.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
            if samples.len() >= 200 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let median = samples[samples.len() / 2];
        let p95_idx = ((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1);
        let p95 = samples[p95_idx];
        let r = CaseResult {
            name: name.to_string(),
            mean_ns: mean,
            median_ns: median,
            p95_ns: p95,
            iters: total_iters,
        };
        println!(
            "  {:<38} mean {:>12}  median {:>12}  p95 {:>12}  ({} iters)",
            r.name,
            fmt_ns(r.mean_ns),
            fmt_ns(r.median_ns),
            fmt_ns(r.p95_ns),
            r.iters
        );
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Write results as JSON lines under results/bench_<group>.json.
    pub fn finish(self) {
        let _ = std::fs::create_dir_all("results");
        let mut out = String::from("[");
        for (i, r) in self.results.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"mean_ns\":{:.1},\"median_ns\":{:.1},\"p95_ns\":{:.1}}}",
                r.name, r.mean_ns, r.median_ns, r.p95_ns
            ));
        }
        out.push(']');
        let _ = std::fs::write(format!("results/bench_{}.json", self.group), out);
    }
}
