//! Minimal property-based testing framework with shrinking.
//!
//! proptest is unavailable offline; this provides the 10% we need: run a
//! property over N random cases from a seeded [`Rng`], and on failure
//! greedily shrink the failing input via a user-supplied shrinker before
//! reporting.  Used by the cache / coordinator invariant tests.
//!
//! ```ignore
//! check(100, gen_requests, shrink_requests, |reqs| {
//!     let c = run_cache(reqs);
//!     c.resident_len() <= c.capacity()
//! });
//! ```

use super::rng::Rng;

/// Run `prop` on `cases` inputs drawn from `gen`.  On failure, apply
/// `shrink` (which yields smaller candidates) greedily until a local
/// minimum, then panic with the minimal counterexample's Debug rendering.
pub fn check<T, G, S, P>(cases: usize, mut gen: G, shrink: S, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> bool,
{
    let seed = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(input, &shrink, &prop);
            panic!(
                "property failed (case {case}, seed {seed}).\nminimal counterexample: {minimal:?}"
            );
        }
    }
}

/// `check` without shrinking.
pub fn check_no_shrink<T, G, P>(cases: usize, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    check(cases, gen, |_| Vec::new(), prop)
}

fn shrink_loop<T, S, P>(mut failing: T, shrink: &S, prop: &P) -> T
where
    T: Clone,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> bool,
{
    // bounded greedy descent
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    failing
}

/// Standard shrinker for vectors: halves, single-element removals, and
/// element-wise shrinks.
pub fn shrink_vec<T: Clone>(v: &[T], elem: impl Fn(&T) -> Vec<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if !v.is_empty() {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
        for i in 0..v.len().min(16) {
            let mut w = v.to_vec();
            w.remove(i);
            out.push(w);
        }
        for i in 0..v.len().min(16) {
            for e in elem(&v[i]) {
                let mut w = v.to_vec();
                w[i] = e;
                out.push(w);
            }
        }
    }
    out
}

/// Shrinker for usize: towards zero.
pub fn shrink_usize(n: &usize) -> Vec<usize> {
    let n = *n;
    let mut out = Vec::new();
    if n > 0 {
        out.push(0);
        out.push(n / 2);
        out.push(n - 1);
    }
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            200,
            |r| r.below(100),
            |n| shrink_usize(n),
            |n| *n < 100,
        );
    }

    #[test]
    #[should_panic(expected = "minimal counterexample: 50")]
    fn failing_property_shrinks_to_boundary() {
        check(
            500,
            |r| r.below(100),
            |n| shrink_usize(n),
            |n| *n < 50, // fails for n >= 50; minimal failing value is 50
        );
    }

    #[test]
    fn vec_shrinker_produces_smaller() {
        let v = vec![3usize, 9, 1];
        for w in shrink_vec(&v, |e| shrink_usize(e)) {
            assert!(w.len() < v.len() || w.iter().sum::<usize>() <= v.iter().sum::<usize>());
        }
    }

    #[test]
    #[should_panic(expected = "minimal counterexample: []")]
    fn trivially_false_shrinks_to_empty_vec() {
        check(
            10,
            |r| (0..r.below(20)).map(|i| i).collect::<Vec<usize>>(),
            |v| shrink_vec(v, |e| shrink_usize(e)),
            |_| false,
        );
    }
}
