//! From-scratch utility substrates.
//!
//! The offline registry carries only the `xla` crate's dependency closure —
//! no serde, clap, rand, proptest or criterion — so the pieces a serving
//! framework normally pulls off crates.io are implemented here:
//! [`json`] (parser + writer), [`cli`] (argument parsing), [`rng`]
//! (splitmix64 / xoshiro256**), and [`prop`] (property-based testing with
//! shrinking).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
