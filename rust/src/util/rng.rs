//! Deterministic PRNGs: splitmix64 (seeding) and xoshiro256** (stream).
//!
//! Used by the workload generators, the property-testing framework and the
//! bench harness.  No external `rand` crate exists in the offline image.

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed into the xoshiro state
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // multiply-shift; bias negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Exponential with given rate (for arrival processes).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let x = r.range(5, 8);
            assert!((5..8).contains(&x));
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(4);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.05, "mean {m}");
        assert!((v - 1.0).abs() < 0.1, "var {v}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(8);
        let s = r.sample_indices(20, 8);
        assert_eq!(s.len(), 8);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 8);
    }
}
