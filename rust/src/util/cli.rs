//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed getters and a generated usage string.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// Declared option names (for usage/validation).
    known: Vec<(String, String)>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Declare an option (for `usage()`); returns self for chaining.
    pub fn declare(mut self, name: &str, help: &str) -> Self {
        self.known.push((name.to_string(), help.to_string()));
        self
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }

    pub fn usage(&self, prog: &str, summary: &str) -> String {
        let mut s = format!("{prog} — {summary}\n\noptions:\n");
        for (name, help) in &self.known {
            s.push_str(&format!("  --{name:<18} {help}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["repro", "table1", "--gpu", "h100", "--n=5", "--verbose"]);
        assert_eq!(a.positional, vec!["repro", "table1"]);
        assert_eq!(a.get("gpu"), Some("h100"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 5);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_f64("r", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn flag_before_positional() {
        let a = parse(&["--fast", "run"]);
        // "run" is consumed as the value of --fast (no '=' given and next
        // token is not an option) — document this parser limitation.
        assert_eq!(a.get("fast"), Some("run"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["--rate=1.25", "--name=x=y"]);
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 1.25);
        assert_eq!(a.get("name"), Some("x=y"));
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["--n", "abc"]);
        assert!(a.get_usize("n", 0).is_err());
    }
}
