//! Fleet fault injection and recovery primitives (docs/ROBUSTNESS.md).
//!
//! The cluster layer assumed every replica, PCIe link and expert
//! transfer was perfect; this module supplies the failure model that
//! turns the existing mechanisms — portable suspended `SeqState`,
//! exactly-one terminal `Outcome`, the big-little fallback — into
//! actual fault tolerance:
//!
//! * [`FaultPlan`] — a deterministic, seedable schedule of injected
//!   faults.  It is drawn from a *dedicated* RNG stream
//!   (`WorkloadSpec::fault_seed`), never the workload generator's, so a
//!   fault-free run with this module compiled in is byte-identical to a
//!   build without it.
//! * [`FaultKind`] — the failure taxonomy: fail-stop replica crashes,
//!   slow-replica brownouts (a compute multiplier over a sim-time
//!   window), PCIe link flaps (bandwidth degradation plus loss of the
//!   in-flight transfer pipeline), and expert-transfer corruption (a
//!   checksum-failed arrival that is discarded, never committed).
//! * [`Health`] — the per-replica state machine the dispatcher keys
//!   routing decisions on (never dispatch to `Down`, de-weight
//!   `Degraded` / `Recovering`).
//! * [`PhiDetector`] — a phi-accrual-style missed-heartbeat detector:
//!   the dispatcher samples every replica's sim-clock progress as a
//!   heartbeat and grows suspicion with the gap, so `Down` is an
//!   *observed* state, not an oracle read.
//! * [`RetryPolicy`] — the per-request retry budget (`--retry <n>`)
//!   with exponential backoff in sim time; a request that exhausts it
//!   resolves with the terminal `Outcome::Failed`.
//!
//! Since the event-driven cluster core landed, the generated
//! [`FaultPlan`] no longer runs as a separate timeline: the dispatcher
//! seeds one `Fault` event per planned injection into the cluster's
//! sim-time event queue, where they interleave deterministically with
//! arrivals, retry wake-ups and steal ticks (docs/CLUSTER.md).

use crate::util::rng::Rng;

/// Salt XORed into the workload seed for the fault RNG stream.  A
/// dedicated stream means fault generation consumes zero draws from the
/// workload generator, so enabling the fault *machinery* (with no
/// faults) can never perturb arrivals, routing traces, or decode
/// numerics.
pub const FAULT_SEED_SALT: u64 = 0xFA17_5EED;

/// Hard cap on generated fault events — a backstop against a
/// degenerate mtbf, far above any meaningful storm.
const MAX_EVENTS: usize = 10_000;

/// Replica health as seen by the dispatcher.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    /// Serving normally.
    Healthy,
    /// Up but impaired: a brownout compute multiplier or a link flap is
    /// active.  Dispatchable, but de-weighted by the balancers.
    Degraded,
    /// Crashed: all state lost, nothing may be dispatched to it.
    Down,
    /// Restarted after a crash but cold (caches empty).  Dispatchable;
    /// flips to [`Health::Healthy`] after its first served step.
    Recovering,
}

impl Health {
    pub fn name(self) -> &'static str {
        match self {
            Health::Healthy => "healthy",
            Health::Degraded => "degraded",
            Health::Down => "down",
            Health::Recovering => "recovering",
        }
    }

    /// Whether the dispatcher may route work here.  `Down` is the only
    /// non-dispatchable state — the invariant `run_cluster` hard-fails
    /// on if violated.
    pub fn dispatchable(self) -> bool {
        !matches!(self, Health::Down)
    }
}

/// One injected failure.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Fail-stop crash: every queued and live sequence is reclaimed by
    /// the dispatcher, VRAM residency and in-flight transfers are lost,
    /// and the replica restarts cold after the spec's recovery delay.
    Crash,
    /// Slow replica: compute is multiplied by `factor` for `duration`
    /// sim-seconds.  Live sequences migrate to healthy replicas with
    /// progress intact (suspended `SeqState` is portable).
    Brownout { factor: f64, duration: f64 },
    /// PCIe link flap: H2D transfer durations are multiplied by
    /// `factor` for `duration` sim-seconds and every tracked in-flight
    /// transfer is lost (must be re-fetched).
    LinkFlap { factor: f64, duration: f64 },
    /// One tracked in-flight expert transfer arrives checksum-corrupt:
    /// it is discarded without committing residency and must be
    /// re-fetched by a later demand miss or prefetch.
    Corrupt,
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Crash => "crash",
            FaultKind::Brownout { .. } => "brownout",
            FaultKind::LinkFlap { .. } => "link-flap",
            FaultKind::Corrupt => "corrupt",
        }
    }
}

/// One scheduled fault: `kind` strikes `replica` at sim-time `at`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at: f64,
    pub replica: usize,
    pub kind: FaultKind,
}

/// Knobs for fault generation (CLI `--faults` / `--mtbf`).  The
/// default [`FaultSpec::none`] is inert: no events, no RNG draws, no
/// trace emissions — fault-free output stays byte-identical.
#[derive(Debug, Clone)]
pub struct FaultSpec {
    pub enabled: bool,
    /// Mean sim-seconds between injected faults, fleet-wide.
    pub mtbf: f64,
    /// Faults are injected in `[0, horizon)` sim-seconds.
    pub horizon: f64,
    /// Crash restart delay: a crashed replica is `Down` for this many
    /// sim-seconds, then `Recovering` (cold).
    pub recovery: f64,
    /// Compute multiplier while a brownout window is active (> 1).
    pub brownout_factor: f64,
    pub brownout_duration: f64,
    /// H2D duration multiplier while a link flap is active (> 1).
    pub flap_factor: f64,
    pub flap_duration: f64,
    /// Relative draw weights for the four fault kinds.
    pub crash_weight: f64,
    pub brownout_weight: f64,
    pub flap_weight: f64,
    pub corrupt_weight: f64,
}

impl FaultSpec {
    /// No faults.  Inert by construction: [`FaultPlan::generate`]
    /// returns an empty plan without touching the RNG.
    pub fn none() -> FaultSpec {
        FaultSpec {
            enabled: false,
            mtbf: 0.0,
            horizon: 0.0,
            recovery: 0.0,
            brownout_factor: 1.0,
            brownout_duration: 0.0,
            flap_factor: 1.0,
            flap_duration: 0.0,
            crash_weight: 0.0,
            brownout_weight: 0.0,
            flap_weight: 0.0,
            corrupt_weight: 0.0,
        }
    }

    /// Crash-only storm: fail-stop crashes at the given mtbf, each
    /// followed by a `recovery`-second cold restart.
    pub fn crash_storm(mtbf: f64, horizon: f64, recovery: f64) -> FaultSpec {
        FaultSpec {
            enabled: true,
            mtbf,
            horizon,
            recovery,
            crash_weight: 1.0,
            ..FaultSpec::none()
        }
    }

    /// All four fault kinds at equal weight.  `scale` is a
    /// characteristic service time (e.g. one request's estimated
    /// service seconds): it sizes the recovery delay and the
    /// brownout / flap windows so the storm is disruptive but
    /// recoverable at any simulated model size.
    pub fn mixed(mtbf: f64, horizon: f64, scale: f64) -> FaultSpec {
        FaultSpec {
            enabled: true,
            mtbf,
            horizon,
            recovery: scale,
            brownout_factor: 3.0,
            brownout_duration: 2.0 * scale,
            flap_factor: 4.0,
            flap_duration: 2.0 * scale,
            crash_weight: 1.0,
            brownout_weight: 1.0,
            flap_weight: 1.0,
            corrupt_weight: 1.0,
        }
    }
}

/// A deterministic schedule of fault events, sorted by time.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Draw a fault schedule from a dedicated RNG stream (`seed` should
    /// be `WorkloadSpec::fault_seed()`).  Inter-fault gaps are
    /// exponential at rate `1/mtbf`, the struck replica is uniform, and
    /// the kind follows the spec's weights.  Disabled or degenerate
    /// specs return an empty plan without consuming any randomness.
    pub fn generate(spec: &FaultSpec, n_replicas: usize, seed: u64) -> FaultPlan {
        let mut events = Vec::new();
        let weight_sum =
            spec.crash_weight + spec.brownout_weight + spec.flap_weight + spec.corrupt_weight;
        if !spec.enabled
            || n_replicas == 0
            || spec.mtbf <= 0.0
            || spec.horizon <= 0.0
            || weight_sum <= 0.0
        {
            return FaultPlan { events };
        }
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        loop {
            t += rng.exp(1.0 / spec.mtbf);
            if t >= spec.horizon || events.len() >= MAX_EVENTS {
                break;
            }
            let replica = rng.below(n_replicas);
            let mut draw = rng.f64() * weight_sum;
            let kind = if draw < spec.crash_weight {
                FaultKind::Crash
            } else {
                draw -= spec.crash_weight;
                if draw < spec.brownout_weight {
                    FaultKind::Brownout {
                        factor: spec.brownout_factor,
                        duration: spec.brownout_duration,
                    }
                } else if draw - spec.brownout_weight < spec.flap_weight {
                    FaultKind::LinkFlap {
                        factor: spec.flap_factor,
                        duration: spec.flap_duration,
                    }
                } else {
                    FaultKind::Corrupt
                }
            };
            events.push(FaultEvent { at: t, replica, kind });
        }
        FaultPlan { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Per-request retry budget with exponential backoff in sim time
/// (CLI `--retry <n>`).  With the budget exhausted a reclaimed request
/// resolves `Outcome::Failed`; [`RetryPolicy::off`] (budget 0) fails
/// on the first reclaim.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    pub max_retries: u32,
    /// Base backoff in sim-seconds; attempt `k` (0-based) waits
    /// `backoff · 2^k` before re-dispatch.
    pub backoff: f64,
}

impl RetryPolicy {
    pub fn off() -> RetryPolicy {
        RetryPolicy { max_retries: 0, backoff: 0.0 }
    }

    pub fn retries(max_retries: u32, backoff: f64) -> RetryPolicy {
        RetryPolicy { max_retries, backoff: backoff.max(0.0) }
    }

    /// Sim-seconds to wait before re-dispatching attempt `attempt`
    /// (0-based): exponential, capped so the shift cannot overflow.
    pub fn delay(&self, attempt: u32) -> f64 {
        self.backoff * f64::from(1u32 << attempt.min(20))
    }
}

/// Phi-accrual-style failure detector.  Each replica's sim-clock
/// progress is its heartbeat; suspicion `phi` grows linearly with the
/// silence gap measured in expected heartbeat intervals, and a replica
/// is *suspected* down once `phi` crosses the threshold.  The
/// dispatcher emits each sample as a `Heartbeat` trace event, so
/// detector behaviour is auditable from the timeline.
#[derive(Debug, Clone)]
pub struct PhiDetector {
    expected: f64,
    threshold: f64,
    last: Vec<f64>,
}

impl PhiDetector {
    /// `expected` is the anticipated gap between heartbeats in
    /// sim-seconds; `threshold` the suspicion level (in expected
    /// intervals of silence) at which a replica is suspected down.
    pub fn new(n_replicas: usize, expected: f64, threshold: f64) -> PhiDetector {
        PhiDetector {
            expected: expected.max(1e-12),
            threshold: threshold.max(1.0),
            last: vec![0.0; n_replicas],
        }
    }

    /// Record a heartbeat from `replica` at sim-time `at`.
    pub fn beat(&mut self, replica: usize, at: f64) {
        if let Some(slot) = self.last.get_mut(replica) {
            if at > *slot {
                *slot = at;
            }
        }
    }

    /// Suspicion level: silence since the last heartbeat, in expected
    /// intervals.  0 immediately after a beat.
    pub fn phi(&self, replica: usize, now: f64) -> f64 {
        let last = self.last.get(replica).copied().unwrap_or(0.0);
        ((now - last) / self.expected).max(0.0)
    }

    pub fn suspect(&self, replica: usize, now: f64) -> bool {
        self.phi(replica, now) > self.threshold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_plan_is_empty_and_draw_free() {
        let plan = FaultPlan::generate(&FaultSpec::none(), 4, 42);
        assert!(plan.is_empty());
        // a disabled spec must not consume RNG draws: generating twice
        // from the same seed trivially matches, and the workload stream
        // (a different seed) is untouched by construction
        let again = FaultPlan::generate(&FaultSpec::none(), 4, 42);
        assert_eq!(plan.events, again.events);
    }

    #[test]
    fn plan_is_deterministic_and_well_formed() {
        let spec = FaultSpec::mixed(0.5, 10.0, 0.1);
        let a = FaultPlan::generate(&spec, 4, 7);
        let b = FaultPlan::generate(&spec, 4, 7);
        assert_eq!(a.events, b.events, "same seed, same plan");
        assert!(!a.is_empty(), "mtbf 0.5 over 10s should draw events");
        let mut prev = 0.0;
        for ev in &a.events {
            assert!(ev.at >= prev, "events sorted by time");
            assert!(ev.at < spec.horizon);
            assert!(ev.replica < 4);
            prev = ev.at;
        }
        let c = FaultPlan::generate(&spec, 4, 8);
        assert_ne!(a.events, c.events, "different seed, different plan");
    }

    #[test]
    fn crash_storm_draws_only_crashes() {
        let spec = FaultSpec::crash_storm(0.25, 8.0, 0.05);
        let plan = FaultPlan::generate(&spec, 3, 11);
        assert!(!plan.is_empty());
        assert!(plan.events.iter().all(|e| e.kind == FaultKind::Crash));
    }

    #[test]
    fn mixed_spec_draws_every_kind() {
        let spec = FaultSpec::mixed(0.02, 40.0, 0.1);
        let plan = FaultPlan::generate(&spec, 4, 3);
        let names: std::collections::HashSet<&str> =
            plan.events.iter().map(|e| e.kind.name()).collect();
        for kind in ["crash", "brownout", "link-flap", "corrupt"] {
            assert!(names.contains(kind), "missing {kind} in a long mixed storm");
        }
    }

    #[test]
    fn retry_delay_doubles_per_attempt() {
        let p = RetryPolicy::retries(3, 0.5);
        assert_eq!(p.delay(0), 0.5);
        assert_eq!(p.delay(1), 1.0);
        assert_eq!(p.delay(2), 2.0);
        assert_eq!(RetryPolicy::off().max_retries, 0);
        assert_eq!(RetryPolicy::off().delay(0), 0.0);
    }

    #[test]
    fn detector_suspects_silence_and_recovers_on_beat() {
        let mut d = PhiDetector::new(2, 0.1, 3.0);
        d.beat(0, 1.0);
        d.beat(1, 1.0);
        assert!(!d.suspect(0, 1.05));
        assert!(d.phi(0, 1.2) > d.phi(0, 1.05), "suspicion grows with silence");
        assert!(d.suspect(0, 1.5), "5 expected intervals of silence");
        d.beat(0, 1.5);
        assert!(!d.suspect(0, 1.55), "a beat clears suspicion");
        // stale beats never move the watermark backwards
        d.beat(1, 0.2);
        assert!((d.phi(1, 1.0) - 0.0).abs() < 1e-12);
        // out-of-range replicas are inert, not a panic
        d.beat(9, 1.0);
        assert!(d.suspect(9, 100.0));
    }

    #[test]
    fn health_dispatchability() {
        assert!(Health::Healthy.dispatchable());
        assert!(Health::Degraded.dispatchable());
        assert!(Health::Recovering.dispatchable());
        assert!(!Health::Down.dispatchable());
        assert_eq!(Health::Down.name(), "down");
    }
}
